//! TCP service exposing the coordinator over the wire protocol.

use super::core::{Coordinator, PushOutcome};
use super::protocol::{err_response, ok_response, read_frame, write_frame, Request};
use crate::averagers::AveragerSpec;
use crate::persist::codec;
use crate::util::json::Json;
use crate::util::pool::ThreadPool;
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

/// A running TCP server; drop (or call [`Server::shutdown`]) to stop.
pub struct Server {
    addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    /// Clones of every live connection (keyed by id) so shutdown can
    /// unblock their handler threads (which otherwise sit in a blocking
    /// read). Handlers deregister on exit, so this holds only live fds.
    conns: ConnRegistry,
    accept_thread: Option<std::thread::JoinHandle<()>>,
}

type ConnRegistry = Arc<std::sync::Mutex<std::collections::HashMap<u64, TcpStream>>>;

impl Server {
    /// Bind `addr` (use port 0 for an ephemeral port) and serve
    /// `coordinator` with `workers` connection-handler threads.
    pub fn start(
        addr: &str,
        coordinator: Arc<Coordinator>,
        workers: usize,
    ) -> Result<Server, String> {
        let listener = TcpListener::bind(addr).map_err(|e| format!("bind {addr}: {e}"))?;
        let local = listener.local_addr().map_err(|e| e.to_string())?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let conns: ConnRegistry =
            Arc::new(std::sync::Mutex::new(std::collections::HashMap::new()));
        let conns2 = conns.clone();
        let pool = ThreadPool::new(workers.max(1));
        let accept_thread = std::thread::Builder::new()
            .name("ata-accept".to_string())
            .spawn(move || {
                let mut next_id: u64 = 0;
                for conn in listener.incoming() {
                    if stop2.load(Ordering::SeqCst) {
                        break;
                    }
                    match conn {
                        Ok(stream) => {
                            // Request/response framing: without NODELAY the
                            // 4-byte length prefix waits on delayed ACKs
                            // (~40ms per roundtrip — measured in
                            // coordinator_throughput before this fix).
                            let _ = stream.set_nodelay(true);
                            let id = next_id;
                            next_id += 1;
                            if let Ok(clone) = stream.try_clone() {
                                conns2.lock().expect("conn registry").insert(id, clone);
                            }
                            let c = coordinator.clone();
                            let reg = conns2.clone();
                            pool.execute(move || {
                                handle_connection(stream, &c);
                                reg.lock().expect("conn registry").remove(&id);
                            });
                        }
                        Err(e) => {
                            crate::log_warn!("server", "accept error: {e}");
                        }
                    }
                }
                // pool drops here, joining handler threads (connections
                // were force-closed by shutdown, so handlers exit).
            })
            .map_err(|e| e.to_string())?;
        crate::log_info!("server", "listening on {local}");
        Ok(Server {
            addr: local,
            stop,
            conns,
            accept_thread: Some(accept_thread),
        })
    }

    /// The bound address (useful with port 0).
    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    /// Stop accepting, force-close live connections, join all threads.
    pub fn shutdown(&mut self) {
        if self.stop.swap(true, Ordering::SeqCst) {
            return;
        }
        // Unblock handlers stuck in read_frame on live connections.
        {
            let guard = self.conns.lock().expect("conn registry");
            for s in guard.values() {
                let _ = s.shutdown(std::net::Shutdown::Both);
            }
        }
        // Wake the blocking accept with a dummy connection.
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.accept_thread.take() {
            let _ = h.join();
        }
        crate::log_info!("server", "shut down");
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn handle_connection(mut stream: TcpStream, coordinator: &Coordinator) {
    let peer = stream
        .peer_addr()
        .map(|a| a.to_string())
        .unwrap_or_else(|_| "?".to_string());
    crate::log_debug!("server", "connection from {peer}");
    loop {
        let frame = match read_frame(&mut stream) {
            Ok(Some(f)) => f,
            Ok(None) => break, // clean EOF
            Err(e) => {
                crate::log_debug!("server", "{peer}: read error: {e}");
                break;
            }
        };
        let response = match Request::from_json(&frame) {
            Ok(req) => dispatch(req, coordinator),
            Err(e) => err_response(&e),
        };
        if let Err(e) = write_frame(&mut stream, &response) {
            crate::log_debug!("server", "{peer}: write error: {e}");
            break;
        }
    }
}

fn dispatch(req: Request, c: &Coordinator) -> Json {
    match req {
        Request::Ping => ok_response(vec![("pong", Json::Bool(true))]),
        Request::Register { stream, dim, spec } => match AveragerSpec::parse(&spec)
            .and_then(|s| c.register(&stream, dim, s))
        {
            Ok(()) => ok_response(vec![]),
            Err(e) => err_response(&e),
        },
        Request::Push { stream, data } => match c.push(&stream, data) {
            Ok(PushOutcome::Accepted) => {
                ok_response(vec![("accepted", Json::Bool(true))])
            }
            Ok(PushOutcome::Dropped) => ok_response(vec![
                ("accepted", Json::Bool(false)),
                ("dropped", Json::Bool(true)),
            ]),
            Err(e) => err_response(&e),
        },
        Request::PushMany {
            stream,
            count,
            data,
        } => {
            // One coordinator call → one shard message; the batch is
            // accepted or dropped as a unit. The parser already paid the
            // allocation, so hand it over instead of pool-copying.
            // (count == 0 and ragged lengths were already rejected as
            // structured error frames by `Request::from_json`; the
            // coordinator re-validates against the stream's declared
            // dim.)
            match c.push_many_owned(&stream, count, data) {
                Ok(PushOutcome::Accepted) => ok_response(vec![
                    ("accepted", Json::Num(count as f64)),
                    ("dropped", Json::Num(0.0)),
                ]),
                Ok(PushOutcome::Dropped) => ok_response(vec![
                    ("accepted", Json::Num(0.0)),
                    ("dropped", Json::Num(count as f64)),
                ]),
                Err(e) => err_response(&e),
            }
        }
        Request::Snapshot { stream } => match c.snapshot(&stream) {
            Ok(snap) => {
                let value = match snap.value {
                    Some(v) => Json::nums(&v),
                    None => Json::Null,
                };
                ok_response(vec![
                    ("stream", Json::Str(snap.stream.to_string())),
                    ("t", Json::Num(snap.t as f64)),
                    ("window_len", Json::Num(snap.window_len)),
                    ("dropped", Json::Num(snap.dropped as f64)),
                    ("value", value),
                ])
            }
            Err(e) => err_response(&e),
        },
        Request::Sync => match c.sync() {
            Ok(()) => ok_response(vec![]),
            Err(e) => err_response(&e),
        },
        Request::Metrics => {
            let mut fields = vec![("metrics", c.metrics().export())];
            let stats: Vec<Json> = c
                .stream_stats()
                .into_iter()
                .map(|(name, applied, dropped, mem)| {
                    Json::obj(vec![
                        ("stream", Json::Str(name)),
                        ("applied", Json::Num(applied as f64)),
                        ("dropped", Json::Num(dropped as f64)),
                        ("memory_floats", Json::Num(mem as f64)),
                    ])
                })
                .collect();
            fields.push(("streams", Json::Arr(stats)));
            ok_response(fields)
        }
        Request::ListStreams => ok_response(vec![(
            "streams",
            Json::Arr(
                c.stream_names()
                    .into_iter()
                    .map(Json::Str)
                    .collect(),
            ),
        )]),
        Request::Checkpoint => match c.checkpoint() {
            Ok(r) => ok_response(vec![
                ("path", Json::Str(r.path.display().to_string())),
                ("seq", Json::Num(r.seq as f64)),
                ("bytes", Json::Num(r.bytes as f64)),
                ("streams", Json::Num(r.streams as f64)),
                (
                    "wal_segments_removed",
                    Json::Num(r.wal_segments_removed as f64),
                ),
            ]),
            Err(e) => err_response(&e),
        },
        Request::ExportState { stream } => match c.export_state(&stream) {
            Ok(bytes) => ok_response(vec![
                ("stream", Json::Str(stream)),
                ("state", Json::Str(codec::to_hex(&bytes))),
            ]),
            Err(e) => err_response(&e),
        },
        Request::Restore { stream, state } => {
            match codec::from_hex(&state).and_then(|b| c.restore_state(&stream, &b)) {
                Ok(t) => ok_response(vec![("t", Json::Num(t as f64))]),
                Err(e) => err_response(&e),
            }
        }
        Request::MergeState { stream, state } => {
            match codec::from_hex(&state).and_then(|b| c.merge_state(&stream, &b)) {
                Ok(t) => ok_response(vec![("t", Json::Num(t as f64))]),
                Err(e) => err_response(&e),
            }
        }
    }
}
