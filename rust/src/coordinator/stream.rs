//! Per-stream state: one estimator plus bookkeeping.

use crate::averagers::{Averager, AveragerSpec};
use crate::persist::codec::{Dec, Enc};

/// A named parameter stream with its tail-average estimator.
pub struct StreamState {
    pub name: String,
    pub dim: usize,
    pub spec: AveragerSpec,
    averager: Box<dyn Averager>,
    /// Samples applied (== averager.t(), kept separately for accounting).
    pub applied: u64,
    /// Samples rejected for shape errors. (Backpressure drops are
    /// counted lock-free on the coordinator's stream slot, not here.)
    pub malformed: u64,
}

impl StreamState {
    pub fn new(name: &str, dim: usize, spec: AveragerSpec) -> Result<StreamState, String> {
        Ok(StreamState {
            name: name.to_string(),
            dim,
            averager: spec.build(dim)?,
            spec,
            applied: 0,
            malformed: 0,
        })
    }

    /// Apply `count` consecutive samples packed flat in `data` through
    /// the estimator's batched [`Averager::observe_many`] path — one
    /// virtual call and one shape check for the whole batch (single
    /// pushes are a `count == 1` batch; there is no separate per-sample
    /// path to drift from this one).
    pub fn apply_many(&mut self, data: &[f64], count: usize) -> Result<(), String> {
        if count == 0 || count.checked_mul(self.dim) != Some(data.len()) {
            self.malformed += count.max(1) as u64;
            return Err(format!(
                "stream '{}': batch has {} values for {count} samples, stream declared {} dims",
                self.name,
                data.len(),
                self.dim
            ));
        }
        self.averager.observe_many(data, count);
        self.applied += count as u64;
        Ok(())
    }

    /// Current estimate (None before any sample).
    pub fn value(&self) -> Option<Vec<f64>> {
        self.averager.value()
    }

    /// Write the current estimate into `out` (length `dim`); `false`
    /// when none exists yet. The allocation-free snapshot read.
    pub fn value_into(&self, out: &mut [f64]) -> bool {
        self.averager.value_into(out)
    }

    /// Streamed weighted moments (see [`Averager::moments_into`]):
    /// writes mean + variance, returns the effective sample size, or
    /// `None` before any sample. The analytics query path.
    pub fn moments_into(&self, mean: &mut [f64], variance: &mut [f64]) -> Option<f64> {
        self.averager.moments_into(mean, variance)
    }

    pub fn t(&self) -> u64 {
        self.averager.t()
    }

    pub fn window_len(&self) -> f64 {
        self.averager.window_len()
    }

    pub fn memory_floats(&self) -> usize {
        self.averager.memory_floats()
    }

    pub fn reset(&mut self) {
        self.averager.reset();
        self.applied = 0;
    }

    /// Append the estimator's canonical state payload (durability path).
    pub fn export_state(&self, enc: &mut Enc) {
        self.averager.export_state(enc);
    }

    /// Restore the estimator from a canonical payload; the `applied`
    /// accounting resyncs to the restored stream position.
    pub fn import_state(&mut self, dec: &mut Dec<'_>) -> Result<(), String> {
        self.averager.import_state(dec)?;
        self.applied = self.averager.t();
        Ok(())
    }

    /// Merge a peer's canonical payload (shard rollup path).
    pub fn merge_state(&mut self, dec: &mut Dec<'_>) -> Result<(), String> {
        self.averager.merge_state(dec)?;
        self.applied = self.averager.t();
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> AveragerSpec {
        AveragerSpec::Gea { c: 0.5 }
    }

    #[test]
    fn apply_and_value() {
        let mut s = StreamState::new("w", 2, spec()).unwrap();
        assert!(s.value().is_none());
        s.apply_many(&[1.0, 2.0], 1).unwrap();
        assert_eq!(s.value().unwrap(), vec![1.0, 2.0]);
        assert_eq!(s.applied, 1);
        assert_eq!(s.t(), 1);
    }

    #[test]
    fn wrong_dim_counted_not_applied() {
        let mut s = StreamState::new("w", 2, spec()).unwrap();
        assert!(s.apply_many(&[1.0], 1).is_err());
        assert_eq!(s.malformed, 1);
        assert_eq!(s.applied, 0);
        assert!(s.value().is_none());
    }

    #[test]
    fn apply_many_batches_and_accounts() {
        let mut s = StreamState::new("w", 2, spec()).unwrap();
        s.apply_many(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0], 3).unwrap();
        assert_eq!(s.applied, 3);
        assert_eq!(s.t(), 3);
        // Ragged and empty batches are malformed, not applied.
        assert!(s.apply_many(&[1.0, 2.0, 3.0], 2).is_err());
        assert!(s.apply_many(&[], 0).is_err());
        assert_eq!(s.malformed, 3);
        assert_eq!(s.applied, 3);
    }

    #[test]
    fn reset_clears() {
        let mut s = StreamState::new("w", 1, spec()).unwrap();
        s.apply_many(&[5.0], 1).unwrap();
        s.reset();
        assert_eq!(s.applied, 0);
        assert!(s.value().is_none());
    }
}
