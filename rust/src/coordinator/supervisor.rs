//! Shard-worker supervision: run a worker loop under `catch_unwind`,
//! quarantine whatever batch was in flight when it died, restart the
//! loop, and feed the caller's poison-stream policy.
//!
//! The supervisor is generic over the quarantine token so it stays
//! decoupled from the coordinator's private slot types: the worker
//! marks the message it is about to process via [`InFlight`], clears
//! the mark once the message is safely applied or staged, and on a
//! panic the supervisor hands the marooned token to the caller's
//! attribution callback (which bumps per-stream strike counts and
//! isolates repeat offenders instead of letting one stream take the
//! whole shard down).
//!
//! A worker that panics *outside* any message (torn internal state,
//! bugs in checkpoint handling) restarts without attribution; the
//! restart counter still makes the churn visible to operators. The
//! queue, the WAL writer, and the bank staging map are owned by the
//! frame *around* [`supervise`], so a restart loses none of the
//! already-acknowledged work they hold.

use crate::metrics::Counter;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Arc, Mutex};

/// The message a worker is currently processing (`None` between
/// messages). The mutex is uncontended (worker and supervisor are the
/// same thread) and recovers from poisoning by construction — being
/// poisoned mid-panic is its normal operating condition.
pub struct InFlight<T> {
    cell: Mutex<Option<T>>,
}

impl<T> InFlight<T> {
    pub fn new() -> InFlight<T> {
        InFlight {
            cell: Mutex::new(None),
        }
    }

    /// Mark `token` as being processed.
    pub fn begin(&self, token: T) {
        *self.lock() = Some(token);
    }

    /// The message was applied (or staged) — nothing left to quarantine.
    pub fn clear(&self) {
        *self.lock() = None;
    }

    fn take(&self) -> Option<T> {
        self.lock().take()
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, Option<T>> {
        self.cell.lock().unwrap_or_else(|e| e.into_inner())
    }
}

impl<T> Default for InFlight<T> {
    fn default() -> Self {
        Self::new()
    }
}

/// Supervision counters (shared with the coordinator's registry).
pub struct Supervisor {
    /// Worker restarts after a panic.
    pub restarts: Arc<Counter>,
    /// In-flight batches quarantined by those panics.
    pub quarantined: Arc<Counter>,
    /// Flight-recorder dump hook: called after each panic, before the
    /// restart, and its non-empty output is logged — so the last things
    /// the worker did ride along with the panic report. `None` = no
    /// recorder attached (tests, bare coordinators).
    pub dump: Option<Box<dyn Fn() -> String + Send>>,
}

/// Run `body` (one worker incarnation) until it returns cleanly,
/// restarting it after every panic. Each restart quarantines the
/// in-flight token, if the panic struck mid-message, and reports it to
/// `attribute`.
pub fn supervise<T, F, Q>(worker: &str, sup: &Supervisor, mut attribute: Q, mut body: F)
where
    F: FnMut(&InFlight<T>),
    Q: FnMut(T),
{
    let inflight = InFlight::new();
    loop {
        match catch_unwind(AssertUnwindSafe(|| body(&inflight))) {
            Ok(()) => break,
            Err(payload) => {
                sup.restarts.inc();
                crate::log_warn!(
                    "supervisor",
                    "{worker} panicked ({}); restarting",
                    panic_message(payload.as_ref())
                );
                if let Some(dump) = &sup.dump {
                    let tail = dump();
                    if !tail.is_empty() {
                        crate::log_warn!(
                            "supervisor",
                            "{worker} flight-recorder tail:\n{tail}"
                        );
                    }
                }
                if let Some(token) = inflight.take() {
                    sup.quarantined.inc();
                    attribute(token);
                }
            }
        }
    }
}

fn panic_message(payload: &(dyn std::any::Any + Send)) -> &str {
    payload
        .downcast_ref::<&'static str>()
        .copied()
        .or_else(|| payload.downcast_ref::<String>().map(String::as_str))
        .unwrap_or("non-string panic payload")
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};

    fn sup() -> Supervisor {
        Supervisor {
            restarts: Arc::new(Counter::new()),
            quarantined: Arc::new(Counter::new()),
            dump: None,
        }
    }

    #[test]
    fn clean_exit_runs_once() {
        let s = sup();
        let runs = AtomicU64::new(0);
        supervise(
            "w",
            &s,
            |_t: u64| {},
            |_inflight| {
                runs.fetch_add(1, Ordering::Relaxed);
            },
        );
        assert_eq!(runs.load(Ordering::Relaxed), 1);
        assert_eq!(s.restarts.get(), 0);
        assert_eq!(s.quarantined.get(), 0);
    }

    #[test]
    fn panics_restart_and_attribute_the_inflight_token() {
        let s = sup();
        let runs = AtomicU64::new(0);
        let mut quarantined: Vec<u64> = Vec::new();
        supervise(
            "w",
            &s,
            |t: u64| quarantined.push(t),
            |inflight| {
                let n = runs.fetch_add(1, Ordering::Relaxed);
                match n {
                    // Incarnation 0 dies mid-message 7; incarnation 1
                    // dies between messages; incarnation 2 exits clean.
                    0 => {
                        inflight.begin(7);
                        panic!("boom in message");
                    }
                    1 => panic!("boom between messages"),
                    _ => {}
                }
            },
        );
        assert_eq!(runs.load(Ordering::Relaxed), 3);
        assert_eq!(s.restarts.get(), 2);
        assert_eq!(s.quarantined.get(), 1);
        assert_eq!(quarantined, vec![7]);
    }

    #[test]
    fn dump_hook_fires_on_each_panic() {
        let dumps = Arc::new(AtomicU64::new(0));
        let s = Supervisor {
            restarts: Arc::new(Counter::new()),
            quarantined: Arc::new(Counter::new()),
            dump: Some(Box::new({
                let dumps = Arc::clone(&dumps);
                move || {
                    dumps.fetch_add(1, Ordering::Relaxed);
                    "  [0ns shard 0] push trace_id=1 handle=2 arg=3\n".to_string()
                }
            })),
        };
        let runs = AtomicU64::new(0);
        supervise(
            "w",
            &s,
            |_t: u64| {},
            |_inflight| {
                if runs.fetch_add(1, Ordering::Relaxed) < 2 {
                    panic!("boom");
                }
            },
        );
        assert_eq!(dumps.load(Ordering::Relaxed), 2, "one dump per panic");
        assert_eq!(s.restarts.get(), 2);
    }

    #[test]
    fn cleared_tokens_are_not_quarantined() {
        let s = sup();
        let first = AtomicU64::new(0);
        supervise(
            "w",
            &s,
            |_t: u64| panic!("must not attribute a cleared token"),
            |inflight| {
                if first.fetch_add(1, Ordering::Relaxed) == 0 {
                    inflight.begin(1);
                    inflight.clear();
                    panic!("after clear");
                }
            },
        );
        assert_eq!(s.restarts.get(), 1);
        assert_eq!(s.quarantined.get(), 0);
    }
}
