//! # ATA — Anytime Tail Averaging
//!
//! A streaming iterate-averaging framework reproducing and productionizing
//! *Anytime Tail Averaging* (Nicolas Le Roux, 2019).
//!
//! Tail averaging keeps the mean of the last `k_t` samples of a stream
//! (`k_t = k` fixed, or `k_t = ct` growing). Exact computation costs
//! `O(k_t)` memory, which is prohibitive when each sample is the parameter
//! vector of a large model. This crate implements the paper's two
//! constant-memory *anytime* estimators —
//!
//! * the **growing exponential average** ([`averagers::GrowingExp`]), an EMA
//!   whose decay is re-solved every step so the estimator variance tracks
//!   `1/(ct)` exactly, and
//! * the **anytime window average** ([`averagers::Awa2`],
//!   [`averagers::AwaMulti`]), a bank of `z+1` accumulators whose optimal
//!   recombination achieves the exact-window variance at every timestep —
//!
//! together with the exact and classical baselines the paper compares
//! against, an analysis toolkit that reconstructs the per-sample weights of
//! any estimator, a multi-stream coordinator service, and the paper's full
//! stochastic-linear-regression evaluation harness.
//!
//! ## Batched ingestion
//!
//! The ingest hot path is *batched end-to-end*: every estimator
//! implements [`averagers::Averager::observe_many`] natively (closed-form
//! decay folds, run-fused mean kernels, block-aware ring updates — see
//! `averagers::kernels`), the AWA accumulator banks are single
//! contiguous SoA allocations, and the coordinator carries `(count,
//! flat-data)` batches through its shard queues in pooled, reusable
//! buffers ([`util::pool::BufferPool`]) — one message per batch, zero
//! steady-state allocation. Same-spec streams fuse into **planar banks**
//! ([`averagers::banked`]): one structure-of-arrays arena per
//! `(spec, dim)`, applied with one lock acquisition and one virtual
//! dispatch per bank per drain cycle and published through an epoch-flip
//! (seqlock) protocol so snapshots are wait-free. The `PushMany` wire
//! op, the [`linreg`] experiment harness, and the bench suites all ride
//! this path; batched-vs-sequential and bank-vs-slot equivalence are
//! property-tested to 1e-12 for every estimator family.
//!
//! ## Anytime analytics
//!
//! Every estimator natively tracks the second raw moment of its
//! weighted tail ([`averagers::Averager::moments_into`]): an `x²` twin
//! of the value accumulators updated with the identical recurrence, so
//! weighted variance and effective sample size (`ESS = 1/Σα²`) stream
//! in O(d) without replay. The [`analytics`] layer turns those moments
//! into [`analytics::StatSnapshot`]s (mean ± confidence band over the
//! effective window), pools them across streams with the ESS-weighted
//! parallel-Welford combine, and ranks deviants — served through the
//! coordinator's `query`/`multi_snapshot` wire ops (both protocol
//! generations, results identical to 1e-12) and the `ata query` CLI.
//!
//! ## Durable state
//!
//! Constant-memory estimators cannot be recomputed after a crash
//! without replaying the stream, so every estimator's state is a
//! serializable, mergeable partial aggregate ([`persist`]):
//! [`averagers::Averager::export_state`] / `import_state` round-trip
//! the full state through a versioned binary codec (bitwise-stable,
//! 1e-12-equivalent to the uninterrupted stream when restored
//! mid-stream, banked and slot backings interchangeable), and
//! `merge_state` combines shard partials (exact accumulator pooling
//! for exp/gea/awa, precedence for windowed estimators). The
//! coordinator layers a per-shard write-ahead log, atomic checkpoint
//! snapshots with bulk per-bank arena encoding, and crash recovery
//! (`Coordinator::recover`) on top — exposed through the wire protocol
//! (`checkpoint`/`export_state`/`restore`/`merge_state`) and the
//! `ata checkpoint` / `ata restore` CLI.
//!
//! ## Wire protocol v2
//!
//! The serving surface ([`coordinator::protocol`]) negotiates its codec
//! per connection: **v2** (default) is a binary format built on the
//! persist layer's `Enc`/`Dec` primitives — `register`/`resolve` return
//! a `u64` stream **handle** every hot op addresses streams by (no
//! per-request string hashing), every frame carries a client-chosen
//! sequence id so requests **pipeline** (responses matched by id;
//! barrier ops complete out of order on a server side-pool), and
//! `multi_push` carries batches for many handles in one frame. f64
//! payloads travel as raw little-endian bits and state transfers as raw
//! CRC-framed bytes. **v1** (the legacy length-prefixed JSON codec) is
//! auto-detected for peers whose first frame is not a `hello`, and kept
//! bit-compatible. Frame I/O enforces `MAX_FRAME` in both directions
//! and runs through pooled buffers ([`util::pool::BufferPool`]).
//!
//! ## Architecture (three layers)
//!
//! * **L3 (this crate)** — the coordinator: averager state management,
//!   stream routing, backpressure, snapshots, metrics, CLI, experiment
//!   harness. Everything on the request path is Rust.
//! * **L2 (JAX, build time)** — the evaluation workload (batched SGD on the
//!   paper's linear-regression problem) as jitted JAX functions, lowered
//!   once to XLA HLO text by `python/compile/aot.py`.
//! * **L1 (Pallas, build time)** — the dense kernels (batched gradient,
//!   accumulator combines) called from L2, validated against a pure-jnp
//!   oracle.
//!
//! [`runtime`] loads the AOT artifacts via the PJRT C API and executes them
//! from Rust; Python never runs at serving/experiment time.
//!
//! ## Quick start
//!
//! ```
//! use ata::averagers::{Averager, AwaMulti, WindowKind};
//!
//! // Anytime average over a growing window k_t = 0.5·t, 3 accumulators.
//! let mut avg = AwaMulti::new(1, WindowKind::Growing { c: 0.5 }, 2);
//! for t in 0..1000u64 {
//!     let x = (t as f64).sin();
//!     avg.observe(&[x]);
//! }
//! let mut out = [0.0];
//! avg.value_into(&mut out);
//! assert!(out[0].abs() < 1.0);
//! ```
pub mod analytics;
pub mod averagers;
pub mod benchkit;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod linreg;
pub mod metrics;
pub mod obs;
pub mod persist;
pub mod report;
pub mod rng;
pub mod runtime;
pub mod stats;
pub mod testkit;
pub mod util;

/// Crate version string (mirrors `Cargo.toml`).
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
