//! Multi-run experiment harness — regenerates the paper's Figures 2–3.
//!
//! For each of `runs` independent seeds: run constant-stepsize SGD, feed
//! every iterate to every estimator under study, and record each
//! estimator's excess error on the evaluation schedule. Curves are
//! averaged across runs (the paper uses 100 runs) with standard errors.

use super::problem::LinRegProblem;
use super::schedule::EvalSchedule;
use super::sgd::{Sgd, SgdConfig};
use crate::averagers::AveragerSpec;
use crate::util::json::Json;
use crate::util::pool::ThreadPool;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Full experiment specification.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    pub problem: LinRegProblem,
    pub sgd: SgdConfig,
    /// Number of SGD steps (batches) per run — paper: 1000.
    pub total_steps: u64,
    /// Independent repetitions — paper: 100.
    pub runs: u64,
    /// Root seed; run `r` uses substream `r`.
    pub seed: u64,
    /// Estimators to evaluate.
    pub averagers: Vec<AveragerSpec>,
    pub schedule: EvalSchedule,
    /// Also record the unaveraged iterate's excess error as a curve.
    pub include_iterate: bool,
}

impl ExperimentConfig {
    /// Paper Figure 2 (one panel): constant window `k`, estimators
    /// `expk` / `awa` (2 accumulators) / `truek`, §4 workload.
    pub fn figure2(k: u64, runs: u64) -> ExperimentConfig {
        use crate::averagers::WindowKind::Fixed;
        ExperimentConfig {
            problem: LinRegProblem::paper_default(),
            sgd: SgdConfig::paper_default(),
            total_steps: 1000,
            runs,
            seed: 20190221, // paper date as default root seed
            averagers: vec![
                AveragerSpec::ExpK { k },
                AveragerSpec::Awa {
                    window: Fixed { k },
                    accumulators: 2,
                },
                AveragerSpec::True { window: Fixed { k } },
            ],
            schedule: EvalSchedule::EveryStep,
            include_iterate: true,
        }
    }

    /// Paper Figure 3 (one panel): growing window `k_t = ct`, estimators
    /// `raw` / `exp` (GEA) / `awa` / `awa3` / `true`, §4 workload.
    pub fn figure3(c: f64, runs: u64) -> ExperimentConfig {
        use crate::averagers::WindowKind::Growing;
        let total_steps = 1000;
        ExperimentConfig {
            problem: LinRegProblem::paper_default(),
            sgd: SgdConfig::paper_default(),
            total_steps,
            runs,
            seed: 20190221,
            averagers: vec![
                AveragerSpec::Raw {
                    c,
                    total_steps,
                },
                AveragerSpec::Gea { c },
                AveragerSpec::Awa {
                    window: Growing { c },
                    accumulators: 2,
                },
                AveragerSpec::Awa {
                    window: Growing { c },
                    accumulators: 3,
                },
                AveragerSpec::True {
                    window: Growing { c },
                },
            ],
            schedule: EvalSchedule::EveryStep,
            include_iterate: true,
        }
    }

    pub fn validate(&self) -> Result<(), String> {
        self.sgd.validate(&self.problem)?;
        if self.total_steps == 0 || self.runs == 0 {
            return Err("total_steps and runs must be >= 1".into());
        }
        if self.averagers.is_empty() && !self.include_iterate {
            return Err("nothing to evaluate".into());
        }
        for spec in &self.averagers {
            spec.build(self.problem.d)?; // surfaces spec errors early
        }
        Ok(())
    }
}

/// One estimator's mean excess-error curve with standard errors.
#[derive(Clone, Debug)]
pub struct Curve {
    pub label: String,
    pub mean: Vec<f64>,
    pub stderr: Vec<f64>,
}

impl Curve {
    /// Final mean excess error.
    pub fn final_value(&self) -> f64 {
        *self.mean.last().expect("nonempty curve")
    }

    /// JSON form (for dumps and golden comparisons).
    pub fn to_json(&self, steps: &[u64]) -> Json {
        Json::obj(vec![
            ("label", Json::Str(self.label.clone())),
            (
                "steps",
                Json::Arr(steps.iter().map(|&s| Json::Num(s as f64)).collect()),
            ),
            ("mean", Json::nums(&self.mean)),
            ("stderr", Json::nums(&self.stderr)),
        ])
    }
}

/// Aggregated experiment output.
#[derive(Clone, Debug)]
pub struct ExperimentResult {
    /// Evaluation steps (shared x-axis).
    pub steps: Vec<u64>,
    pub curves: Vec<Curve>,
    pub runs: u64,
    pub wall: Duration,
}

impl ExperimentResult {
    /// Look up a curve by label substring.
    pub fn curve(&self, label_part: &str) -> Option<&Curve> {
        self.curves.iter().find(|c| c.label.contains(label_part))
    }

    /// JSON dump of the whole result.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("runs", Json::Num(self.runs as f64)),
            ("wall_seconds", Json::Num(self.wall.as_secs_f64())),
            (
                "curves",
                Json::Arr(
                    self.curves
                        .iter()
                        .map(|c| c.to_json(&self.steps))
                        .collect(),
                ),
            ),
        ])
    }
}

/// Excess-error trajectories of every estimator for ONE run.
/// `out[est][eval_idx]`; estimator order = `cfg.averagers` (+ iterate last
/// when `include_iterate`).
fn run_single(cfg: &ExperimentConfig, run_index: u64, eval_steps: &[u64]) -> Vec<Vec<f64>> {
    /// Iterates per estimator feed: large enough to amortize per-batch
    /// dispatch, small enough that the flat block stays cache-resident
    /// (64 × d=50 × 8B = 25 KiB).
    const BLOCK: usize = 64;
    let d = cfg.problem.d;
    let mut sgd = Sgd::substream(cfg.problem.clone(), cfg.sgd, cfg.seed, run_index)
        .expect("validated config");
    let mut avgs: Vec<_> = cfg
        .averagers
        .iter()
        .map(|s| s.build(d).expect("validated config"))
        .collect();
    let n_series = avgs.len() + usize::from(cfg.include_iterate);
    let mut out = vec![Vec::with_capacity(eval_steps.len()); n_series];
    let mut wbar = vec![0.0; d];
    let mut block: Vec<f64> = Vec::with_capacity(BLOCK * d);
    let mut eval_iter = eval_steps.iter().peekable();
    let mut t = 0u64;
    while t < cfg.total_steps {
        // Advance SGD to the next estimator-visible boundary — the next
        // eval step or the block cap — and feed the whole iterate block
        // through every estimator's batched path in one call each.
        let next_eval = eval_iter
            .peek()
            .map(|&&e| e)
            .unwrap_or(cfg.total_steps)
            .min(cfg.total_steps);
        let chunk = ((next_eval - t) as usize).clamp(1, BLOCK);
        block.clear();
        sgd.steps_into(chunk, &mut block);
        t += chunk as u64;
        for a in &mut avgs {
            a.observe_many(&block, chunk);
        }
        if eval_iter.peek() == Some(&&t) {
            eval_iter.next();
            for (i, a) in avgs.iter().enumerate() {
                let err = if a.value_into(&mut wbar) {
                    cfg.problem.excess_error(&wbar)
                } else {
                    f64::NAN
                };
                out[i].push(err);
            }
            if cfg.include_iterate {
                let err = cfg.problem.excess_error(sgd.w());
                out[n_series - 1].push(err);
            }
        }
    }
    out
}

/// Run the experiment, parallelizing runs over `pool` when provided.
pub fn run_experiment(
    cfg: &ExperimentConfig,
    pool: Option<&ThreadPool>,
) -> Result<ExperimentResult, String> {
    cfg.validate()?;
    let t0 = Instant::now();
    let eval_steps = cfg.schedule.steps(cfg.total_steps);
    let runs = cfg.runs as usize;

    let per_run: Vec<Vec<Vec<f64>>> = match pool {
        Some(pool) => {
            let cfg_arc = Arc::new(cfg.clone());
            let steps_arc = Arc::new(eval_steps.clone());
            pool.map_indexed(runs, move |r| {
                run_single(&cfg_arc, r as u64, &steps_arc)
            })
        }
        None => (0..runs)
            .map(|r| run_single(cfg, r as u64, &eval_steps))
            .collect(),
    };

    // Aggregate across runs: mean and stderr per estimator per eval step.
    let n_series = per_run[0].len();
    let n_eval = eval_steps.len();
    let mut labels: Vec<String> = cfg.averagers.iter().map(|s| s.label()).collect();
    if cfg.include_iterate {
        labels.push("iterate".to_string());
    }
    let mut curves = Vec::with_capacity(n_series);
    for s in 0..n_series {
        let mut mean = vec![0.0; n_eval];
        let mut m2 = vec![0.0; n_eval];
        for run in &per_run {
            for (e, &v) in run[s].iter().enumerate() {
                mean[e] += v;
                m2[e] += v * v;
            }
        }
        let n = runs as f64;
        for e in 0..n_eval {
            mean[e] /= n;
            let var = (m2[e] / n - mean[e] * mean[e]).max(0.0);
            m2[e] = (var / n).sqrt(); // standard error of the mean
        }
        curves.push(Curve {
            label: labels[s].clone(),
            mean,
            stderr: m2,
        });
    }
    Ok(ExperimentResult {
        steps: eval_steps,
        curves,
        runs: cfg.runs,
        wall: t0.elapsed(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_fig3(c: f64) -> ExperimentConfig {
        let mut cfg = ExperimentConfig::figure3(c, 8);
        cfg.total_steps = 300;
        cfg.schedule = EvalSchedule::LogSpaced { points: 30 };
        cfg
    }

    #[test]
    fn shapes_are_consistent() {
        let cfg = small_fig3(0.5);
        let res = run_experiment(&cfg, None).unwrap();
        assert_eq!(res.curves.len(), 6); // 5 estimators + iterate
        for c in &res.curves {
            assert_eq!(c.mean.len(), res.steps.len());
            assert_eq!(c.stderr.len(), res.steps.len());
            assert!(c.mean.iter().all(|v| v.is_finite()), "{}", c.label);
        }
    }

    #[test]
    fn averaged_curves_beat_iterate_at_end() {
        // Needs the paper's full 1000-step horizon: the slow
        // eigendirections (λ = 1/50) only leave their transient late, and
        // tail averaging wins once the iterate sits in the noise ball.
        // c = 0.25 so the window excludes most of the transient.
        let mut cfg = ExperimentConfig::figure3(0.25, 8);
        cfg.schedule = EvalSchedule::LogSpaced { points: 30 };
        let res = run_experiment(&cfg, None).unwrap();
        let iterate = res.curve("iterate").unwrap().final_value();
        let truec = res.curve("true").unwrap().final_value();
        let awa3 = res.curve("awa3").unwrap().final_value();
        assert!(truec < iterate, "true {truec} vs iterate {iterate}");
        assert!(awa3 < iterate, "awa3 {awa3} vs iterate {iterate}");
    }

    #[test]
    fn parallel_equals_serial() {
        let cfg = small_fig3(0.25);
        let pool = ThreadPool::new(4);
        let serial = run_experiment(&cfg, None).unwrap();
        let parallel = run_experiment(&cfg, Some(&pool)).unwrap();
        for (a, b) in serial.curves.iter().zip(&parallel.curves) {
            assert_eq!(a.label, b.label);
            for (x, y) in a.mean.iter().zip(&b.mean) {
                assert_eq!(x, y, "parallel must be bit-identical");
            }
        }
    }

    #[test]
    fn deterministic_across_invocations() {
        let cfg = small_fig3(0.5);
        let a = run_experiment(&cfg, None).unwrap();
        let b = run_experiment(&cfg, None).unwrap();
        for (ca, cb) in a.curves.iter().zip(&b.curves) {
            assert_eq!(ca.mean, cb.mean);
        }
    }

    #[test]
    fn figure2_preset_shapes() {
        let mut cfg = ExperimentConfig::figure2(10, 4);
        cfg.total_steps = 200;
        cfg.schedule = EvalSchedule::Strided { stride: 10 };
        let res = run_experiment(&cfg, None).unwrap();
        assert_eq!(res.curves.len(), 4); // expk, awa2, truek, iterate
        assert!(res.curve("expk").is_some());
        assert!(res.curve("awa2").is_some());
        assert!(res.curve("true").is_some());
    }

    #[test]
    fn json_roundtrip() {
        let cfg = small_fig3(0.5);
        let res = run_experiment(&cfg, None).unwrap();
        let j = res.to_json();
        let parsed = Json::parse(&j.encode()).unwrap();
        assert_eq!(
            parsed.get("runs").and_then(Json::as_u64),
            Some(cfg.runs)
        );
        assert_eq!(
            parsed.get("curves").unwrap().as_arr().unwrap().len(),
            res.curves.len()
        );
    }

    #[test]
    fn validation_rejects_empty() {
        let mut cfg = small_fig3(0.5);
        cfg.averagers.clear();
        cfg.include_iterate = false;
        assert!(run_experiment(&cfg, None).is_err());
    }
}
