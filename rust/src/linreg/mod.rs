//! The paper's evaluation workload: stochastic linear regression (§4).
//!
//! Minimize `ℓ(w) = E_{x,y}(xᵀw − y)²` with `x ~ N(0, H)`,
//! `H = diag(1/i)` (`50×50`), `y ~ N(xᵀw*, ε)`, `ε² = 0.01`, by
//! constant-stepsize mini-batch SGD (batch 11), averaging the iterates
//! with each estimator under study and plotting the *excess error*
//! `ℓ(w̄) − ℓ(w*) = (w̄−w*)ᵀH(w̄−w*)` over 1000 batches, mean of 100 runs.
//!
//! * [`problem`] — the data-generating process and exact excess error.
//! * [`sgd`] — native constant-stepsize SGD (the pure-Rust reference
//!   path; the AOT/PJRT path in [`crate::runtime`] executes the same
//!   update compiled from JAX and is cross-checked against this).
//! * [`experiment`] — the multi-run harness reproducing Figures 2–3.
//! * [`schedule`] — evaluation-step schedules for curve sampling.

pub mod experiment;
pub mod problem;
pub mod schedule;
pub mod sgd;

pub use experiment::{run_experiment, Curve, ExperimentConfig, ExperimentResult};
pub use problem::LinRegProblem;
pub use schedule::EvalSchedule;
pub use sgd::{Sgd, SgdConfig};
