//! The data-generating process of the paper's experiments (§4).

use crate::rng::{GaussianSource, RngCore};

/// Stochastic linear regression problem `ℓ(w) = E(xᵀw − y)²` with
/// diagonal-Gaussian covariates `x ~ N(0, diag(h))` and observation noise
/// `y ~ N(xᵀw*, ε)`.
///
/// Because `H` is diagonal the excess error
/// `ℓ(w) − ℓ(w*) = (w−w*)ᵀH(w−w*)` is computable in `O(d)` — the
/// experiment harness evaluates it at every step for every estimator.
#[derive(Clone, Debug)]
pub struct LinRegProblem {
    /// Dimension `d`.
    pub d: usize,
    /// Diagonal of `H` (`h[i] = H_{ii} > 0`).
    pub spectrum: Vec<f64>,
    /// `√h[i]`, cached for sampling.
    scales: Vec<f64>,
    /// Ground-truth weights `w*`.
    pub w_star: Vec<f64>,
    /// Observation-noise standard deviation `ε`.
    pub noise_std: f64,
}

impl LinRegProblem {
    /// Build from explicit pieces.
    pub fn new(spectrum: Vec<f64>, w_star: Vec<f64>, noise_std: f64) -> Result<Self, String> {
        if spectrum.is_empty() || spectrum.len() != w_star.len() {
            return Err("spectrum and w_star must be nonempty and equal length".into());
        }
        if spectrum.iter().any(|&h| h <= 0.0) {
            return Err("spectrum entries must be positive".into());
        }
        if noise_std < 0.0 {
            return Err("noise_std must be nonnegative".into());
        }
        let scales = spectrum.iter().map(|&h| h.sqrt()).collect();
        Ok(LinRegProblem {
            d: spectrum.len(),
            spectrum,
            scales,
            w_star,
            noise_std,
        })
    }

    /// The paper's §4 configuration: `d = 50`, `H_ii = 1/i` (1-based),
    /// `ε² = 0.01`, and `w* = 1` (the paper does not specify `w*`; any
    /// fixed vector only shifts the initial excess error, and ones gives
    /// the O(1) initial excess visible in the figures).
    pub fn paper_default() -> Self {
        let d = 50;
        let spectrum: Vec<f64> = (1..=d).map(|i| 1.0 / i as f64).collect();
        let w_star = vec![1.0; d];
        LinRegProblem::new(spectrum, w_star, 0.1).expect("valid defaults")
    }

    /// Largest eigenvalue of `H` (stepsize stability bound).
    pub fn lambda_max(&self) -> f64 {
        self.spectrum.iter().cloned().fold(f64::MIN, f64::max)
    }

    /// `tr(H) = Σ h_i` (enters the stochastic stepsize bound).
    pub fn trace(&self) -> f64 {
        self.spectrum.iter().sum()
    }

    /// Sample a batch: fills `xs` (row-major `b×d`) and `ys` (`b`).
    pub fn sample_batch<R: RngCore>(
        &self,
        g: &mut GaussianSource<R>,
        xs: &mut [f64],
        ys: &mut [f64],
    ) {
        let b = ys.len();
        assert_eq!(xs.len(), b * self.d, "xs must be b×d");
        for (row, y) in xs.chunks_exact_mut(self.d).zip(ys.iter_mut()) {
            let mut dot = 0.0;
            for ((x, &s), &w) in row.iter_mut().zip(&self.scales).zip(&self.w_star) {
                *x = s * g.next_gaussian();
                dot += *x * w;
            }
            *y = dot + self.noise_std * g.next_gaussian();
        }
    }

    /// Excess error `(w−w*)ᵀH(w−w*)` — the paper's plotted quantity.
    pub fn excess_error(&self, w: &[f64]) -> f64 {
        assert_eq!(w.len(), self.d);
        let mut acc = 0.0;
        for ((&wi, &wsi), &hi) in w.iter().zip(&self.w_star).zip(&self.spectrum) {
            let dlt = wi - wsi;
            acc += hi * dlt * dlt;
        }
        acc
    }

    /// Full expected loss `ℓ(w) = excess + ε²`.
    pub fn loss(&self, w: &[f64]) -> f64 {
        self.excess_error(w) + self.noise_std * self.noise_std
    }

    /// The irreducible loss `ℓ(w*) = ε²`.
    pub fn optimal_loss(&self) -> f64 {
        self.noise_std * self.noise_std
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256;

    #[test]
    fn paper_default_shape() {
        let p = LinRegProblem::paper_default();
        assert_eq!(p.d, 50);
        assert_eq!(p.spectrum[0], 1.0);
        assert!((p.spectrum[49] - 1.0 / 50.0).abs() < 1e-15);
        assert!((p.optimal_loss() - 0.01).abs() < 1e-15);
        assert_eq!(p.lambda_max(), 1.0);
        // Initial excess from w=0: Σ 1/i ≈ 4.499
        let zero = vec![0.0; 50];
        let harmonic: f64 = (1..=50).map(|i| 1.0 / i as f64).sum();
        assert!((p.excess_error(&zero) - harmonic).abs() < 1e-12);
    }

    #[test]
    fn excess_error_is_zero_at_optimum() {
        let p = LinRegProblem::paper_default();
        assert_eq!(p.excess_error(&p.w_star.clone()), 0.0);
        assert_eq!(p.loss(&p.w_star.clone()), p.optimal_loss());
    }

    #[test]
    fn batch_statistics_match_model() {
        let p = LinRegProblem::paper_default();
        let mut g = GaussianSource::new(Xoshiro256::seed_from_u64(12));
        let b = 11;
        let n_batches = 3000;
        let mut var_x0 = 0.0; // coordinate 0: variance 1
        let mut var_xlast = 0.0; // coordinate 49: variance 1/50
        let mut resid_var = 0.0; // y − xᵀw*: variance ε²
        let mut xs = vec![0.0; b * p.d];
        let mut ys = vec![0.0; b];
        for _ in 0..n_batches {
            p.sample_batch(&mut g, &mut xs, &mut ys);
            for (row, &y) in xs.chunks_exact(p.d).zip(&ys) {
                var_x0 += row[0] * row[0];
                var_xlast += row[49] * row[49];
                let fit: f64 = row.iter().zip(&p.w_star).map(|(a, b)| a * b).sum();
                let r = y - fit;
                resid_var += r * r;
            }
        }
        let n = (n_batches * b) as f64;
        var_x0 /= n;
        var_xlast /= n;
        resid_var /= n;
        assert!((var_x0 - 1.0).abs() < 0.03, "var_x0={var_x0}");
        assert!((var_xlast - 0.02).abs() < 0.002, "var_xlast={var_xlast}");
        assert!((resid_var - 0.01).abs() < 0.001, "resid_var={resid_var}");
    }

    #[test]
    fn excess_error_weights_by_spectrum() {
        // An error along a low-eigenvalue direction matters less.
        let p = LinRegProblem::paper_default();
        let mut w_hi = p.w_star.clone();
        w_hi[0] += 1.0; // eigenvalue 1
        let mut w_lo = p.w_star.clone();
        w_lo[49] += 1.0; // eigenvalue 1/50
        assert!((p.excess_error(&w_hi) - 1.0).abs() < 1e-12);
        assert!((p.excess_error(&w_lo) - 0.02).abs() < 1e-12);
    }

    #[test]
    fn validation_rejects_bad_input() {
        assert!(LinRegProblem::new(vec![], vec![], 0.1).is_err());
        assert!(LinRegProblem::new(vec![1.0], vec![1.0, 2.0], 0.1).is_err());
        assert!(LinRegProblem::new(vec![0.0], vec![1.0], 0.1).is_err());
        assert!(LinRegProblem::new(vec![1.0], vec![1.0], -0.1).is_err());
    }
}
