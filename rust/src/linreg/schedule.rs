//! Evaluation-step schedules for curve sampling.

/// Which steps to evaluate (and plot) the estimators at.
///
/// The paper plots full curves on a log–log scale; `LogSpaced` reproduces
/// the visually equivalent sampling at a fraction of the evaluation cost,
/// while `EveryStep` gives exact curves for tests and small runs.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum EvalSchedule {
    /// Evaluate at every step `1..=total`.
    EveryStep,
    /// Evaluate at `points` log-spaced steps between 1 and `total`
    /// (deduplicated, always includes both endpoints).
    LogSpaced { points: usize },
    /// Evaluate every `stride` steps (always includes the final step).
    Strided { stride: u64 },
}

impl EvalSchedule {
    /// Materialize the (sorted, unique, 1-based) evaluation steps.
    pub fn steps(&self, total: u64) -> Vec<u64> {
        assert!(total >= 1);
        match *self {
            EvalSchedule::EveryStep => (1..=total).collect(),
            EvalSchedule::LogSpaced { points } => {
                let points = points.max(2);
                let lo = 0.0f64;
                let hi = (total as f64).ln();
                let mut out: Vec<u64> = (0..points)
                    .map(|i| {
                        let f = lo + (hi - lo) * i as f64 / (points - 1) as f64;
                        f.exp().round().clamp(1.0, total as f64) as u64
                    })
                    .collect();
                out.sort_unstable();
                out.dedup();
                out
            }
            EvalSchedule::Strided { stride } => {
                let stride = stride.max(1);
                let mut out: Vec<u64> = (1..=total).filter(|t| t % stride == 0).collect();
                if out.last() != Some(&total) {
                    out.push(total);
                }
                if out.first() != Some(&1) {
                    out.insert(0, 1);
                }
                out
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_step_is_complete() {
        assert_eq!(EvalSchedule::EveryStep.steps(5), vec![1, 2, 3, 4, 5]);
    }

    #[test]
    fn log_spaced_includes_endpoints_and_is_sorted() {
        let s = EvalSchedule::LogSpaced { points: 20 }.steps(1000);
        assert_eq!(*s.first().unwrap(), 1);
        assert_eq!(*s.last().unwrap(), 1000);
        assert!(s.windows(2).all(|w| w[0] < w[1]));
        assert!(s.len() <= 20);
        assert!(s.len() >= 10);
    }

    #[test]
    fn log_spaced_handles_tiny_totals() {
        assert_eq!(EvalSchedule::LogSpaced { points: 50 }.steps(1), vec![1]);
        assert_eq!(EvalSchedule::LogSpaced { points: 50 }.steps(2), vec![1, 2]);
    }

    #[test]
    fn strided_includes_first_and_last() {
        let s = EvalSchedule::Strided { stride: 3 }.steps(10);
        assert_eq!(s, vec![1, 3, 6, 9, 10]);
        let s = EvalSchedule::Strided { stride: 5 }.steps(10);
        assert_eq!(s, vec![1, 5, 10]);
    }
}
