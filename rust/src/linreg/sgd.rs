//! Constant-stepsize mini-batch SGD on the linear-regression problem.

use super::problem::LinRegProblem;
use crate::rng::{GaussianSource, Xoshiro256};

/// SGD hyperparameters.
#[derive(Clone, Copy, Debug)]
pub struct SgdConfig {
    /// Mini-batch size `b` (paper: 11).
    pub batch_size: usize,
    /// Constant stepsize `η`; the update is
    /// `w ← w − (η/b)·Σ_i x_i(x_iᵀw − y_i)` (the factor 2 of the squared
    /// loss is absorbed into `η`, as is conventional).
    pub step_size: f64,
}

impl SgdConfig {
    /// The paper's batch size with a stepsize calibrated so the §4 figure
    /// shapes reproduce: the fast eigendirections reach the noise ball
    /// within ~100 steps while the slow ones (λ = 1/50) stay in transient
    /// through t = 1000, which is the regime where staleness separates the
    /// methods (paper Figures 2–3). η = 0.2 is also the scale Jain et
    /// al. [2018]-style constant-stepsize analyses prescribe
    /// (η ≈ 1/tr(H) ≈ 0.22 for this spectrum). See EXPERIMENTS.md for the
    /// stepsize sweep; larger η (0.4) ends the transient so early that the
    /// stationary autocorrelation effect lets the EMA *win*, inverting the
    /// paper's Figure-2 ordering.
    pub fn paper_default() -> SgdConfig {
        SgdConfig {
            batch_size: 11,
            step_size: 0.2,
        }
    }

    pub fn validate(&self, problem: &LinRegProblem) -> Result<(), String> {
        if self.batch_size == 0 {
            return Err("batch_size must be >= 1".into());
        }
        if self.step_size <= 0.0 {
            return Err("step_size must be positive".into());
        }
        // Deterministic-GD stability needs η < 2/λmax; the stochastic
        // bound is tighter but this catches gross misconfiguration.
        let bound = 2.0 / problem.lambda_max();
        if self.step_size >= bound {
            return Err(format!(
                "step_size {} ≥ 2/λmax = {bound}: divergent even in expectation",
                self.step_size
            ));
        }
        Ok(())
    }
}

/// A single SGD trajectory with its own data stream.
///
/// Deterministic given `(problem, config, seed)`; the experiment harness
/// runs many of these in parallel with substream seeds. Scratch buffers
/// are preallocated — `step()` performs no allocation.
pub struct Sgd {
    problem: LinRegProblem,
    cfg: SgdConfig,
    w: Vec<f64>,
    gauss: GaussianSource<Xoshiro256>,
    // Scratch
    xs: Vec<f64>,
    ys: Vec<f64>,
    resid: Vec<f64>,
    step: u64,
}

impl Sgd {
    /// Start from `w₀ = 0` with data substream `seed`.
    pub fn new(problem: LinRegProblem, cfg: SgdConfig, seed: u64) -> Result<Sgd, String> {
        cfg.validate(&problem)?;
        let d = problem.d;
        let b = cfg.batch_size;
        Ok(Sgd {
            problem,
            cfg,
            w: vec![0.0; d],
            gauss: GaussianSource::new(Xoshiro256::seed_from_u64(seed)),
            xs: vec![0.0; b * d],
            ys: vec![0.0; b],
            resid: vec![0.0; b],
            step: 0,
        })
    }

    /// As [`Sgd::new`] but seeded as substream `index` of `root_seed`
    /// (independent parallel runs).
    pub fn substream(
        problem: LinRegProblem,
        cfg: SgdConfig,
        root_seed: u64,
        index: u64,
    ) -> Result<Sgd, String> {
        cfg.validate(&problem)?;
        let d = problem.d;
        let b = cfg.batch_size;
        Ok(Sgd {
            problem,
            cfg,
            w: vec![0.0; d],
            gauss: GaussianSource::new(Xoshiro256::substream(root_seed, index)),
            xs: vec![0.0; b * d],
            ys: vec![0.0; b],
            resid: vec![0.0; b],
            step: 0,
        })
    }

    /// Current iterate.
    pub fn w(&self) -> &[f64] {
        &self.w
    }

    /// Steps taken.
    pub fn step_count(&self) -> u64 {
        self.step
    }

    /// Problem accessor.
    pub fn problem(&self) -> &LinRegProblem {
        &self.problem
    }

    /// One mini-batch update; returns the new iterate.
    ///
    /// `w ← w − (η/b) Xᵀ(Xw − y)` with `X ∈ R^{b×d}` row-major. This is
    /// the hot loop of the native path; the `(b,d)` GEMV pair below is the
    /// same contraction the L1 Pallas kernel implements.
    pub fn step(&mut self) -> &[f64] {
        let d = self.problem.d;
        let b = self.cfg.batch_size;
        self.problem
            .sample_batch(&mut self.gauss, &mut self.xs, &mut self.ys);
        // resid = Xw − y
        for (i, r) in self.resid.iter_mut().enumerate() {
            let row = &self.xs[i * d..(i + 1) * d];
            let mut dot = 0.0;
            for (&x, &w) in row.iter().zip(&self.w) {
                dot += x * w;
            }
            *r = dot - self.ys[i];
        }
        // w -= (η/b) Xᵀ resid
        let scale = self.cfg.step_size / b as f64;
        for i in 0..b {
            let coeff = scale * self.resid[i];
            let row = &self.xs[i * d..(i + 1) * d];
            for (w, &x) in self.w.iter_mut().zip(row) {
                *w -= coeff * x;
            }
        }
        self.step += 1;
        &self.w
    }

    /// Advance `n` steps, appending each post-step iterate (`d` floats)
    /// to `out` — the flat `(n, d)` row-major block the estimators'
    /// batched `observe_many` path ingests without re-entering
    /// per-sample dispatch. Reuses `out`'s capacity across calls.
    pub fn steps_into(&mut self, n: usize, out: &mut Vec<f64>) {
        out.reserve(n * self.problem.d);
        for _ in 0..n {
            self.step();
            out.extend_from_slice(&self.w);
        }
    }

    /// Excess error of the current iterate.
    pub fn excess_error(&self) -> f64 {
        self.problem.excess_error(&self.w)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn paper_sgd(seed: u64) -> Sgd {
        Sgd::new(
            LinRegProblem::paper_default(),
            SgdConfig::paper_default(),
            seed,
        )
        .unwrap()
    }

    #[test]
    fn loss_decreases_then_plateaus() {
        let mut sgd = paper_sgd(7);
        let initial = sgd.excess_error();
        for _ in 0..200 {
            sgd.step();
        }
        let mid = sgd.excess_error();
        assert!(
            mid < initial / 20.0,
            "excess should fall sharply: {initial} -> {mid}"
        );
        // Run to 1000 and confirm we are hovering in a noise ball, not
        // diverging: every window of the tail stays small.
        let mut max_tail: f64 = 0.0;
        for _ in 200..1000 {
            sgd.step();
            max_tail = max_tail.max(sgd.excess_error());
        }
        assert!(max_tail < initial / 5.0, "tail max {max_tail}");
    }

    #[test]
    fn averaged_iterate_beats_last_iterate() {
        // The whole point of tail averaging: averaged excess ≪ iterate
        // excess once the iterate sits in the noise ball. At the paper
        // stepsize the slow directions keep a transient through t = 1000,
        // so run past it (T = 4000, window c = 0.25) where the stationary
        // variance reduction dominates; average over a few seeds to avoid
        // single-run noise.
        use crate::averagers::{Averager, TrueWindow, WindowKind};
        let mut last_sum = 0.0;
        let mut avg_sum = 0.0;
        for seed in 0..5 {
            let mut sgd = paper_sgd(seed);
            let mut avg = TrueWindow::new(50, WindowKind::Growing { c: 0.25 });
            for _ in 0..4000 {
                let w = sgd.step().to_vec();
                avg.observe(&w);
            }
            last_sum += sgd.excess_error();
            let mut wbar = vec![0.0; 50];
            assert!(avg.value_into(&mut wbar));
            avg_sum += sgd.problem().excess_error(&wbar);
        }
        assert!(
            avg_sum < last_sum / 2.0,
            "averaging should help: iterate {last_sum}, averaged {avg_sum}"
        );
    }

    #[test]
    fn steps_into_matches_stepwise_iterates() {
        let mut a = paper_sgd(3);
        let mut b = paper_sgd(3);
        let mut block = Vec::new();
        a.steps_into(5, &mut block);
        assert_eq!(block.len(), 5 * 50);
        let mut last = Vec::new();
        for _ in 0..5 {
            last = b.step().to_vec();
        }
        assert_eq!(&block[4 * 50..], &last[..]);
        assert_eq!(a.w(), b.w());
        assert_eq!(a.step_count(), 5);
        // Appends (does not clear) so callers can accumulate blocks.
        a.steps_into(2, &mut block);
        assert_eq!(block.len(), 7 * 50);
    }

    #[test]
    fn deterministic_per_seed() {
        let mut a = paper_sgd(42);
        let mut b = paper_sgd(42);
        for _ in 0..50 {
            a.step();
            b.step();
        }
        assert_eq!(a.w(), b.w());
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = paper_sgd(1);
        let mut b = paper_sgd(2);
        for _ in 0..10 {
            a.step();
            b.step();
        }
        assert_ne!(a.w(), b.w());
    }

    #[test]
    fn substream_runs_are_independent_and_deterministic() {
        let p = LinRegProblem::paper_default;
        let cfg = SgdConfig::paper_default();
        let mut r0 = Sgd::substream(p(), cfg, 9, 0).unwrap();
        let mut r0b = Sgd::substream(p(), cfg, 9, 0).unwrap();
        let mut r1 = Sgd::substream(p(), cfg, 9, 1).unwrap();
        for _ in 0..20 {
            r0.step();
            r0b.step();
            r1.step();
        }
        assert_eq!(r0.w(), r0b.w());
        assert_ne!(r0.w(), r1.w());
    }

    #[test]
    fn validate_rejects_divergent_stepsize() {
        let p = LinRegProblem::paper_default();
        let cfg = SgdConfig {
            batch_size: 11,
            step_size: 2.5,
        };
        assert!(cfg.validate(&p).is_err());
        assert!(Sgd::new(p, cfg, 0).is_err());
    }

    #[test]
    fn validate_rejects_zero_batch() {
        let p = LinRegProblem::paper_default();
        let cfg = SgdConfig {
            batch_size: 0,
            step_size: 0.1,
        };
        assert!(cfg.validate(&p).is_err());
    }

    #[test]
    fn noise_ball_scale_is_reasonable() {
        // Stationary excess of constant-stepsize SGD scales like
        // η·ε²·tr(H)/(2b) up to constants; check the measured ball is in
        // a plausible band rather than wildly off (guards against
        // gradient-scaling bugs).
        let mut sgd = paper_sgd(11);
        for _ in 0..500 {
            sgd.step();
        }
        let mut acc = 0.0;
        let n = 500;
        for _ in 0..n {
            sgd.step();
            acc += sgd.excess_error();
        }
        let ball = acc / n as f64;
        assert!(ball > 1e-5 && ball < 5e-2, "noise ball {ball}");
    }
}
