//! `ata` — launcher for the Anytime Tail Averaging framework.
//!
//! ```text
//! ata experiment [--config f.toml] [--figure fig3] [--c 0.5] [--k 100]
//!                [--runs 100] [--csv out.csv] [--json out.json]
//! ata serve      [--config svc.toml] [--addr 127.0.0.1:7311]
//! ata client     <ping|list|snapshot|metrics|prom> [--addr ...] [--stream s]
//! ata top        [--addr ...] [--interval-ms 1000] [--once]
//!                                       # live introspection dashboard
//! ata query      [--prefix p] [--streams a,b] [--z 1.96] [--top-k 5]
//!                [--aggregate]          # moment stats + confidence bands
//! ata checkpoint [--addr ...]           # snapshot a running service
//! ata restore    --dir state [...]      # offline crash recovery + report
//! ata route      <announce|place|register|query|snapshot|migrate> --config svc.toml [...]
//!                                       # scatter-gather over a [cluster] ring
//! ata standby    --addr 127.0.0.1:7411 --dir standby-state
//!                                       # warm WAL-replication standby
//! ata artifacts  [--dir artifacts]      # validate AOT artifacts load+run
//! ata weights    --spec "gea(c=0.5)" --t 200   # weight-profile analysis
//! ata bench-compare <baseline.json> <current.json> [--threshold 0.15]
//! ```

use ata::averagers::{staleness_report, AveragerSpec};
use ata::config::{ExperimentFile, PersistConfig, ServiceConfig};
use ata::coordinator::{Client, ClientError, Coordinator, ProtocolChoice, Server, ServerOptions};
use ata::persist::checkpoint::Checkpointer;
use ata::linreg::{run_experiment, EvalSchedule, ExperimentConfig};
use ata::report;
use ata::runtime::{artifacts_available, Runtime, DEFAULT_ARTIFACTS_DIR};
use ata::util::cli::{CliError, CommandSpec};
use ata::util::pool::ThreadPool;
use std::sync::Arc;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match run(&args) {
        Ok(()) => 0,
        Err(CliRunError::Help(text)) => {
            println!("{text}");
            0
        }
        Err(CliRunError::Fail(msg)) => {
            eprintln!("error: {msg}");
            2
        }
    };
    std::process::exit(code);
}

enum CliRunError {
    Help(String),
    Fail(String),
}

impl From<String> for CliRunError {
    fn from(s: String) -> Self {
        CliRunError::Fail(s)
    }
}

impl From<ClientError> for CliRunError {
    fn from(e: ClientError) -> Self {
        CliRunError::Fail(e.to_string())
    }
}

fn top_help() -> String {
    format!(
        "ata {} — anytime tail averaging framework\n\n\
         Commands:\n\
         \x20 experiment   run the paper's §4 experiments (figures 2/3 or a config)\n\
         \x20 serve        start the averaging coordinator TCP service\n\
         \x20 client       talk to a running service\n\
         \x20 top          live introspection dashboard (shards, banks, streams, traces)\n\
         \x20 query        anytime analytics: mean ± band, ESS, top-K deviants\n\
         \x20 checkpoint   snapshot a running durable service over the wire\n\
         \x20 restore      offline crash recovery of a persist directory\n\
         \x20 route        federated client over a [cluster] consistent-hash ring\n\
         \x20 standby      warm standby receiving WAL-shipping replication\n\
         \x20 artifacts    validate the AOT artifacts (load + execute)\n\
         \x20 weights      weight/staleness analysis of an averager spec\n\
         \x20 bench-compare  diff a fresh BENCH json against a committed baseline\n\n\
         Run `ata <command> --help` for details.",
        ata::VERSION
    )
}

fn run(args: &[String]) -> Result<(), CliRunError> {
    let Some(cmd) = args.first() else {
        return Err(CliRunError::Help(top_help()));
    };
    let rest = &args[1..];
    match cmd.as_str() {
        "experiment" => cmd_experiment(rest),
        "serve" => cmd_serve(rest),
        "client" => cmd_client(rest),
        "top" => cmd_top(rest),
        "query" => cmd_query(rest),
        "checkpoint" => cmd_checkpoint(rest),
        "restore" => cmd_restore(rest),
        "route" => cmd_route(rest),
        "standby" => cmd_standby(rest),
        "artifacts" => cmd_artifacts(rest),
        "weights" => cmd_weights(rest),
        "bench-compare" => cmd_bench_compare(rest),
        "--help" | "-h" | "help" => Err(CliRunError::Help(top_help())),
        other => Err(CliRunError::Fail(format!(
            "unknown command '{other}'; try --help"
        ))),
    }
}

fn parse_with(spec: &CommandSpec, args: &[String]) -> Result<ata::util::cli::Parsed, CliRunError> {
    spec.parse(args).map_err(|e| match e {
        CliError::HelpRequested => CliRunError::Help(spec.help_text("ata")),
        other => CliRunError::Fail(other.to_string()),
    })
}

fn cmd_experiment(args: &[String]) -> Result<(), CliRunError> {
    let spec = CommandSpec::new("experiment", "run the paper's linear-regression experiments")
        .opt("config", "", "TOML experiment config (overrides presets)")
        .opt("figure", "fig3", "preset: fig2 | fig3")
        .opt("k", "100", "fig2 window size")
        .opt("c", "0.5", "fig3 window fraction")
        .opt("runs", "100", "independent runs")
        .opt("steps", "1000", "SGD steps per run")
        .opt("eval-points", "0", "log-spaced eval points (0 = every step)")
        .opt("csv", "", "write full curves to CSV file")
        .opt("json", "", "write full result to JSON file")
        .opt("rows", "25", "table rows to print")
        .flag("no-iterate", "omit the unaveraged iterate curve");
    let p = parse_with(&spec, args)?;

    let mut cfg: ExperimentConfig = if !p.str("config").is_empty() {
        ExperimentFile::load(&p.str("config"))?.config
    } else {
        let runs = p.u64("runs").map_err(|e| e.to_string())?;
        match p.str("figure").as_str() {
            "fig2" => ExperimentConfig::figure2(p.u64("k").map_err(|e| e.to_string())?, runs),
            "fig3" => ExperimentConfig::figure3(p.f64("c").map_err(|e| e.to_string())?, runs),
            other => return Err(format!("unknown figure '{other}' (fig2|fig3)").into()),
        }
    };
    if p.str("config").is_empty() {
        cfg.total_steps = p.u64("steps").map_err(|e| e.to_string())?;
        let pts = p.u64("eval-points").map_err(|e| e.to_string())?;
        if pts > 0 {
            cfg.schedule = EvalSchedule::LogSpaced {
                points: pts as usize,
            };
        }
        if p.flag("no-iterate") {
            cfg.include_iterate = false;
        }
    }

    let pool = ThreadPool::with_default_size();
    eprintln!(
        "running {} runs x {} steps on {} workers ...",
        cfg.runs,
        cfg.total_steps,
        pool.size()
    );
    let res = run_experiment(&cfg, Some(&pool))?;
    println!(
        "{}",
        report::render_curves(&res, p.usize("rows").map_err(|e| e.to_string())?)
    );
    println!("{}", report::render_summary(&res));
    eprintln!("wall time: {:?}", res.wall);

    let csv = p.str("csv");
    if !csv.is_empty() {
        std::fs::write(&csv, report::to_csv(&res)).map_err(|e| format!("write {csv}: {e}"))?;
        eprintln!("wrote {csv}");
    }
    let json = p.str("json");
    if !json.is_empty() {
        std::fs::write(&json, res.to_json().encode_pretty())
            .map_err(|e| format!("write {json}: {e}"))?;
        eprintln!("wrote {json}");
    }
    Ok(())
}

fn cmd_serve(args: &[String]) -> Result<(), CliRunError> {
    let spec = CommandSpec::new("serve", "start the averaging coordinator service")
        .opt("config", "", "TOML service config")
        .opt("addr", "127.0.0.1:7311", "listen address")
        .opt("shards", "4", "ingest worker shards")
        .opt("workers", "8", "connection handler threads")
        .opt(
            "protocol",
            "",
            "wire codec policy: auto | v1 | v2 (default from config, else auto)",
        );
    let p = parse_with(&spec, args)?;

    // Block SIGTERM/SIGINT before ANY worker thread spawns: the mask is
    // inherited, so a process-directed termination signal queues on the
    // signalfd instead of killing an arbitrary shard or handler thread.
    let watcher = ata::util::signal::termination_watcher();

    let mut cfg = if !p.str("config").is_empty() {
        ServiceConfig::load(&p.str("config"))?
    } else {
        ServiceConfig {
            addr: p.str("addr"),
            shards: p.usize("shards").map_err(|e| e.to_string())?,
            ..Default::default()
        }
    };
    if !p.str("protocol").is_empty() {
        cfg.protocol = ProtocolChoice::parse(&p.str("protocol"))?;
    }
    // A durable service recovers whatever its persist directory holds
    // (snapshot + WAL tails) before listening; a fresh directory is
    // simply an empty recovery.
    let coordinator = if cfg.persist.is_some() {
        let (c, report) = Coordinator::recover(&cfg)?;
        eprintln!(
            "recovered {} streams, replayed {} batches ({} samples){}",
            report.restored_streams + report.replayed_registers as usize,
            report.replayed_batches,
            report.replayed_samples,
            if report.wal_clean {
                ""
            } else {
                " — WAL tail was truncated at a torn record (expected after a crash)"
            }
        );
        Arc::new(c)
    } else {
        Arc::new(Coordinator::from_config(&cfg)?)
    };
    // Background checkpointing, when configured.
    let _checkpointer = cfg
        .persist
        .as_ref()
        .filter(|pc| pc.checkpoint_interval_ms > 0)
        .map(|pc| {
            let c = Arc::clone(&coordinator);
            Checkpointer::start(
                std::time::Duration::from_millis(pc.checkpoint_interval_ms),
                move || c.checkpoint().map(|_| ()),
            )
        });
    // WAL-shipping replication, when this node has a standby configured:
    // a background thread tails committed WAL positions and streams raw
    // segment bytes to the standby's listener.
    let ship_stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
    let shipper_thread = match cfg.cluster.as_ref().and_then(|cl| cl.standby_addr.clone()) {
        Some(standby_addr) if cfg.persist.is_some() => {
            let interval = std::time::Duration::from_millis(
                cfg.cluster.as_ref().map_or(200, |cl| cl.ship_interval_ms).max(10),
            );
            let standby = ata::coordinator::RetryingClient::connect(&standby_addr);
            let shipper =
                ata::cluster::Shipper::new(Arc::clone(&coordinator), standby)?;
            let stop = Arc::clone(&ship_stop);
            eprintln!("shipping WAL to standby {standby_addr} every {interval:?}");
            Some(std::thread::spawn(move || shipper.run(interval, stop)))
        }
        Some(_) => {
            return Err(
                "[cluster].standby_addr requires a [persist] section (the WAL is what ships)"
                    .to_string()
                    .into(),
            )
        }
        None => None,
    };
    let mut server = Server::start_with_options(
        &cfg.addr,
        coordinator,
        p.usize("workers").map_err(|e| e.to_string())?,
        ServerOptions::from_config(&cfg),
    )?;
    eprintln!(
        "serving on {} (protocol {}) — Ctrl-C or SIGTERM to drain and stop",
        cfg.addr,
        cfg.protocol.label()
    );
    match watcher {
        Some(w) => {
            let sig = w.wait();
            eprintln!("{} received — draining connections", sig.label());
            // Drain: stop accepting, let in-flight frames settle, force
            // a WAL group commit, then close. The grace bounds how long
            // a stalled peer can hold up the exit.
            server.drain(std::time::Duration::from_secs(5));
            // Stop replication AFTER the drain so the final group
            // commit's bytes get one last shipping pass.
            ship_stop.store(true, std::sync::atomic::Ordering::Relaxed);
            if let Some(t) = shipper_thread {
                let _ = t.join();
            }
            eprintln!("drained; exiting");
            Ok(())
        }
        // No signal support on this target: block until killed.
        None => loop {
            std::thread::sleep(std::time::Duration::from_secs(3600));
        },
    }
}

fn cmd_query(args: &[String]) -> Result<(), CliRunError> {
    let spec = CommandSpec::new(
        "query",
        "anytime analytics over a running service: mean ± confidence band, ESS, top-K deviants",
    )
    .opt("addr", "127.0.0.1:7311", "server address")
    .opt("prefix", "", "stream-name prefix filter (empty = every stream)")
    .opt(
        "streams",
        "",
        "comma-separated explicit stream list (one multi_snapshot frame; \
         overrides --prefix and ignores --z/--top-k/--aggregate)",
    )
    .opt("z", "1.96", "confidence-band multiplier (prefix mode)")
    .opt("top-k", "0", "keep only the K most deviant streams (0 = all; prefix mode)")
    .flag("aggregate", "also report the cross-stream pooled aggregate (prefix mode)")
    .opt("protocol", "auto", "wire codec: auto | v1 | v2");
    let p = parse_with(&spec, args)?;
    let mut client = Client::connect_with(
        &p.str("addr"),
        ProtocolChoice::parse(&p.str("protocol"))?,
    )?;
    let streams = p.str("streams");
    if !streams.is_empty() {
        if p.flag("aggregate") || p.u64("top-k").map_err(|e| e.to_string())? > 0 {
            eprintln!(
                "note: --aggregate/--top-k apply to prefix queries only and are \
                 ignored with --streams"
            );
        }
        let names: Vec<&str> = streams
            .split(',')
            .map(str::trim)
            .filter(|s| !s.is_empty())
            .collect();
        for (name, r) in names.iter().zip(client.multi_snapshot(&names)?) {
            match r {
                Ok(s) => print_stat(&s),
                Err(e) => println!("{name}\terror: {e}"),
            }
        }
        return Ok(());
    }
    let (stats, aggregate) = client.query(
        &p.str("prefix"),
        p.f64("z").map_err(|e| e.to_string())?,
        p.u64("top-k").map_err(|e| e.to_string())?,
        p.flag("aggregate"),
    )?;
    if stats.is_empty() {
        println!("no streams matched");
    }
    for s in &stats {
        print_stat(s);
    }
    if let Some(a) = aggregate {
        println!("--");
        print_stat(&a);
    }
    Ok(())
}

/// One analytics row: `name  t/k_eff/ess  mean±band per dim`.
fn print_stat(s: &ata::coordinator::StatEntry) {
    if s.ess <= 0.0 {
        println!("{}\tt=0 <no samples>", s.stream);
        return;
    }
    let cols = s.mean.len().min(4);
    let mut vals = String::new();
    for i in 0..cols {
        if i > 0 {
            vals.push_str("  ");
        }
        vals.push_str(&format!("{:+.5}±{:.5}", s.mean[i], s.band[i]));
    }
    if s.mean.len() > cols {
        vals.push_str(&format!("  … ({} dims)", s.mean.len()));
    }
    println!(
        "{}\tt={} k_eff={:.1} ess={:.1}\t{}",
        s.stream, s.t, s.effective_window, s.ess, vals
    );
}

fn cmd_checkpoint(args: &[String]) -> Result<(), CliRunError> {
    let spec = CommandSpec::new("checkpoint", "snapshot a running durable service")
        .opt("addr", "127.0.0.1:7311", "server address")
        .opt("protocol", "auto", "wire codec: auto | v1 | v2");
    let p = parse_with(&spec, args)?;
    let mut client = Client::connect_with(
        &p.str("addr"),
        ProtocolChoice::parse(&p.str("protocol"))?,
    )?;
    let (path, streams) = client.checkpoint()?;
    println!("checkpoint written: {path} ({streams} streams)");
    Ok(())
}

fn cmd_restore(args: &[String]) -> Result<(), CliRunError> {
    let spec = CommandSpec::new(
        "restore",
        "offline crash recovery: load snapshot + WAL tails, report, re-checkpoint",
    )
    .opt("config", "", "TOML service config (must have a [persist] section)")
    .opt("dir", "", "persist directory (shorthand for a minimal config)")
    .opt("shards", "4", "ingest worker shards for the recovered state");
    let p = parse_with(&spec, args)?;
    let cfg = if !p.str("config").is_empty() {
        ServiceConfig::load(&p.str("config"))?
    } else {
        let dir = p.str("dir");
        if dir.is_empty() {
            return Err("restore requires --config or --dir".to_string().into());
        }
        ServiceConfig {
            shards: p.usize("shards").map_err(|e| e.to_string())?,
            persist: Some(PersistConfig {
                dir,
                ..Default::default()
            }),
            ..Default::default()
        }
    };
    let (c, report) = Coordinator::recover(&cfg)?;
    match &report.snapshot {
        Some(path) => println!("snapshot loaded : {}", path.display()),
        None => println!("snapshot loaded : <none — replayed WAL from the beginning>"),
    }
    println!("restored streams: {}", report.restored_streams);
    println!("replayed        : {} batches / {} samples / {} registrations",
        report.replayed_batches, report.replayed_samples, report.replayed_registers);
    println!(
        "wal tail        : {}",
        if report.wal_clean { "clean" } else { "truncated at a torn record (crash tail)" }
    );
    let mut stats = c.stream_stats();
    stats.sort();
    for (name, applied, dropped, mem) in stats {
        println!("  {name}: t={applied} dropped={dropped} memory_floats={mem}");
    }
    println!("state re-checkpointed; `ata serve` will start from it");
    Ok(())
}

fn cmd_route(args: &[String]) -> Result<(), CliRunError> {
    let spec = CommandSpec::new(
        "route",
        "federated client: place streams on a [cluster] ring, scatter-gather ops across nodes",
    )
    .positional("action", "announce | place | register | query | snapshot | migrate")
    .req("config", "TOML service config with [cluster] (and optionally [client]) sections")
    .opt("stream", "", "stream name (place, register, migrate)")
    .opt("streams", "", "comma-separated stream list (snapshot)")
    .opt("dim", "1", "stream dimensionality (register, migrate)")
    .opt("spec", "gea(c=0.5)", "averager spec (register, migrate)")
    .opt("to", "", "target node id (migrate)")
    .opt("wal-dir", "", "source node's WAL root <persist.dir>/wal (migrate delta replay)")
    .opt("src-shards", "0", "source node's shard count (migrate; 0 = no delta replay)")
    .opt("prefix", "", "stream-name prefix filter (query)")
    .opt("z", "1.96", "confidence-band multiplier (query)")
    .opt("top-k", "0", "keep only the K most deviant streams (query; 0 = all)")
    .flag("aggregate", "also report the cluster-wide pooled aggregate (query)");
    let p = parse_with(&spec, args)?;
    let cfg = ServiceConfig::load(&p.str("config"))?;
    let Some(cluster) = cfg.cluster.as_ref() else {
        return Err("route requires a [cluster] section in the config".to_string().into());
    };
    let mut router = ata::cluster::Router::from_config(cluster, &cfg.client)?;
    match p.positional(0).unwrap_or("") {
        "announce" => {
            let (reached, version) = router.announce()?;
            println!(
                "announced ring v{version} to {reached}/{} nodes",
                router.ring().nodes().len()
            );
        }
        "place" => {
            let stream = required(&p, "stream")?;
            let id = router.route(&stream)?;
            let addr = router.ring().node(&id).map(|n| n.addr.clone()).unwrap_or_default();
            println!("{stream} -> {id} ({addr})");
        }
        "register" => {
            let stream = required(&p, "stream")?;
            let handle = router.register(
                &stream,
                p.usize("dim").map_err(|e| e.to_string())?,
                &p.str("spec"),
            )?;
            println!("registered {stream} on {} (handle {handle})", router.route(&stream)?);
        }
        "query" => {
            let q = router.query(
                &p.str("prefix"),
                p.f64("z").map_err(|e| e.to_string())?,
                p.usize("top-k").map_err(|e| e.to_string())?,
                p.flag("aggregate"),
            )?;
            if q.stats.is_empty() {
                println!("no streams matched");
            }
            for s in &q.stats {
                print_stat(s);
            }
            if let Some(a) = &q.aggregate {
                println!("-- pooled over {} streams", q.aggregated);
                print_stat(a);
            }
        }
        "snapshot" => {
            let streams = p.str("streams");
            let names: Vec<&str> = streams
                .split(',')
                .map(str::trim)
                .filter(|s| !s.is_empty())
                .collect();
            if names.is_empty() {
                return Err("snapshot requires --streams".to_string().into());
            }
            for (name, r) in names.iter().zip(router.multi_snapshot(&names)?) {
                match r {
                    Ok(s) => print_stat(&s),
                    Err(e) => println!("{name}\terror: {e}"),
                }
            }
        }
        "migrate" => {
            let stream = required(&p, "stream")?;
            let to = required(&p, "to")?;
            let wal_dir = p.str("wal-dir");
            let src_shards = p.usize("src-shards").map_err(|e| e.to_string())?;
            let source_wal = if !wal_dir.is_empty() && src_shards > 0 {
                Some((std::path::Path::new(&wal_dir), src_shards))
            } else {
                None
            };
            let report = ata::cluster::migrate_stream(
                &mut router,
                &stream,
                &to,
                p.usize("dim").map_err(|e| e.to_string())?,
                &p.str("spec"),
                source_wal,
            )?;
            println!(
                "migrated {} from {} to {} (delta {} samples, ring v{})",
                report.stream, report.from, report.to, report.delta_samples, report.ring_version
            );
        }
        other => return Err(format!("unknown action '{other}'").into()),
    }
    Ok(())
}

fn required(p: &ata::util::cli::Parsed, key: &str) -> Result<String, CliRunError> {
    let v = p.str(key);
    if v.is_empty() {
        return Err(format!("this action requires --{key}").into());
    }
    Ok(v)
}

fn cmd_standby(args: &[String]) -> Result<(), CliRunError> {
    let spec = CommandSpec::new(
        "standby",
        "warm standby: receive WAL-shipping replication until promoted",
    )
    .opt("addr", "127.0.0.1:7411", "replication listen address")
    .req("dir", "directory for the replicated state (becomes persist.dir on promotion)");
    let p = parse_with(&spec, args)?;
    let watcher = ata::util::signal::termination_watcher();
    let standby = ata::cluster::Standby::start(&p.str("addr"), std::path::Path::new(&p.str("dir")))?;
    eprintln!(
        "standby on {} replicating into {} — promote by pointing `ata serve`'s \
         [persist].dir at it (recovery replays the shipped WAL); Ctrl-C to stop",
        standby.addr(),
        p.str("dir")
    );
    match watcher {
        Some(w) => {
            let sig = w.wait();
            let received = standby.received_bytes();
            standby.stop();
            eprintln!("{} received — standby stopped ({received} WAL bytes replicated)", sig.label());
            Ok(())
        }
        None => loop {
            std::thread::sleep(std::time::Duration::from_secs(3600));
        },
    }
}

fn cmd_client(args: &[String]) -> Result<(), CliRunError> {
    let spec = CommandSpec::new("client", "talk to a running coordinator service")
        .positional("action", "ping | list | snapshot | metrics | prom")
        .opt("addr", "127.0.0.1:7311", "server address")
        .opt("stream", "", "stream name (snapshot)")
        .opt(
            "protocol",
            "auto",
            "wire codec: auto | v1 | v2 (use v1 against pre-v2 servers)",
        );
    let p = parse_with(&spec, args)?;
    let mut client = Client::connect_with(
        &p.str("addr"),
        ProtocolChoice::parse(&p.str("protocol"))?,
    )?;
    match p.positional(0).unwrap_or("") {
        "ping" => {
            client.ping()?;
            println!("pong (protocol v{})", client.protocol_version());
        }
        "list" => {
            for s in client.list_streams_full()? {
                if s.handle != 0 {
                    println!("{}\thandle={} dim={}", s.name, s.handle, s.dim);
                } else {
                    println!("{}", s.name);
                }
            }
        }
        "snapshot" => {
            let stream = p.str("stream");
            if stream.is_empty() {
                return Err("snapshot requires --stream".to_string().into());
            }
            let snap = client.snapshot(&stream)?;
            println!(
                "stream={} t={} k_t={:.1} dropped={}",
                snap.stream, snap.t, snap.window_len, snap.dropped
            );
            match snap.value {
                Some(v) if v.len() <= 16 => println!("value={v:?}"),
                Some(v) => println!("value=[{} floats]", v.len()),
                None => println!("value=<none>"),
            }
        }
        "metrics" => {
            println!("{}", client.metrics()?.encode_pretty());
        }
        "prom" => {
            // Prometheus text exposition — pipe to a file and point a
            // scraper at it, or eyeball the families directly.
            print!("{}", client.metrics_prometheus()?);
        }
        other => return Err(format!("unknown action '{other}'").into()),
    }
    Ok(())
}

fn cmd_top(args: &[String]) -> Result<(), CliRunError> {
    let spec = CommandSpec::new(
        "top",
        "live introspection dashboard: shards, banks, streams, flight events, trace spans",
    )
    .opt("addr", "127.0.0.1:7311", "server address")
    .opt("protocol", "auto", "wire codec: auto | v1 | v2")
    .opt("interval-ms", "1000", "refresh interval")
    .opt("events", "10", "flight-recorder events to show")
    .opt("spans", "5", "recent trace spans to show")
    .flag("once", "print one snapshot and exit (no screen clearing)");
    let p = parse_with(&spec, args)?;
    let mut client = Client::connect_with(
        &p.str("addr"),
        ProtocolChoice::parse(&p.str("protocol"))?,
    )?;
    let interval = std::time::Duration::from_millis(
        p.u64("interval-ms").map_err(|e| e.to_string())?.max(100),
    );
    let events = p.usize("events").map_err(|e| e.to_string())?;
    let spans = p.usize("spans").map_err(|e| e.to_string())?;
    let once = p.flag("once");
    loop {
        let report = client.introspect()?;
        if !once {
            // Clear + home; repaint in place like top(1).
            print!("\x1b[2J\x1b[H");
        }
        print!("{}", render_top(&report, &p.str("addr"), events, spans));
        if once {
            return Ok(());
        }
        std::thread::sleep(interval);
    }
}

/// Render one `ata top` frame from an introspection report.
fn render_top(
    r: &ata::obs::introspect::IntrospectReport,
    addr: &str,
    events: usize,
    spans: usize,
) -> String {
    use std::fmt::Write as _;
    let mut out = String::new();
    let queued: u64 = r.shards.iter().map(|s| s.queue_depth).sum();
    let restarts: u64 = r.shards.iter().map(|s| s.worker_starts.saturating_sub(1)).sum();
    let _ = writeln!(
        out,
        "ata top — {addr}  trace sampling {}/1000  queued {queued}  restarts {restarts}{}",
        r.sample_per_mille,
        if r.wal_skipped_tails > 0 {
            format!("  wal_skipped_tails {}", r.wal_skipped_tails)
        } else {
            String::new()
        }
    );
    // REPLAY is the WAL position recovery replayed up to at boot. On a
    // promoted standby, WAL minus REPLAY at promotion time is exactly
    // the acked-but-unshipped loss; on a long-lived primary the pair
    // shows how much log a failover would have to replay.
    let _ = writeln!(
        out,
        "\nSHARD  QUEUE  STARTS  WAL seg@off        REPLAY seg@off     EVENTS"
    );
    for s in &r.shards {
        let _ = writeln!(
            out,
            "{:>5}  {:>5}  {:>6}  {:>8}@{:<8}  {:>8}@{:<8}  {:>6}",
            s.shard, s.queue_depth, s.worker_starts, s.wal_segment, s.wal_offset,
            s.wal_replay_segment, s.wal_replay_offset,
            s.events_recorded
        );
    }
    if !r.banks.is_empty() {
        let _ = writeln!(out, "\nBANK   DIM    ROWS   FLOATS");
        for b in &r.banks {
            let _ = writeln!(
                out,
                "{:>4}  {:>4}  {:>5}  {:>7}",
                b.index, b.dim, b.rows, b.row_floats
            );
        }
    }
    if !r.streams.is_empty() {
        let _ = writeln!(out, "\nSTREAM            HANDLE  DROPPED  STRIKES  HEALTH");
        for s in &r.streams {
            let _ = writeln!(
                out,
                "{:<16}  {:>6}  {:>7}  {:>7}  {}",
                s.name,
                s.handle,
                s.dropped,
                s.strikes,
                if s.poisoned { "POISONED" } else { "ok" }
            );
        }
    }
    if events > 0 && !r.events.is_empty() {
        let _ = writeln!(out, "\nRECENT EVENTS (newest last)");
        let skip = r.events.len().saturating_sub(events);
        for e in &r.events[skip..] {
            let _ = writeln!(
                out,
                "  {:<11} shard={} trace_id={} handle={} arg={}",
                e.kind.label(),
                e.shard,
                e.trace_id,
                e.handle,
                e.arg
            );
        }
    }
    if spans > 0 && !r.spans.is_empty() {
        let _ = writeln!(out, "\nRECENT TRACE SPANS (µs per stage, newest last)");
        let _ = writeln!(
            out,
            "  {:<20} {:>9} {:>9} {:>9} {:>9} {:>9} {:>9}",
            "TRACE", "admit", "queue", "apply", "wal", "fsync", "ack"
        );
        let skip = r.spans.len().saturating_sub(spans);
        for s in &r.spans[skip..] {
            let us = |ns: u64| ns as f64 / 1_000.0;
            let _ = writeln!(
                out,
                "  {:<20} {:>9.1} {:>9.1} {:>9.1} {:>9.1} {:>9.1} {:>9.1}",
                s.trace_id,
                us(s.stage_ns[0]),
                us(s.stage_ns[1]),
                us(s.stage_ns[2]),
                us(s.stage_ns[3]),
                us(s.stage_ns[4]),
                us(s.stage_ns[5])
            );
        }
    }
    out
}

fn cmd_artifacts(args: &[String]) -> Result<(), CliRunError> {
    let spec = CommandSpec::new("artifacts", "validate the AOT artifacts: load, compile, run")
        .opt("dir", DEFAULT_ARTIFACTS_DIR, "artifacts directory");
    let p = parse_with(&spec, args)?;
    let dir = p.str("dir");
    if !artifacts_available(&dir) {
        return Err(format!("no manifest in '{dir}' — run `make artifacts` first").into());
    }
    let rt = Runtime::from_dir(&dir)?;
    let names: Vec<String> = rt.manifest().entries.keys().cloned().collect();
    for name in names {
        let entry = rt.load(&name)?;
        // Execute with zero inputs of the declared shapes as a smoke run.
        let zeros: Vec<Vec<f32>> = entry
            .spec()
            .inputs
            .iter()
            .map(|t| vec![0.0f32; t.elements()])
            .collect();
        let refs: Vec<&[f32]> = zeros.iter().map(Vec::as_slice).collect();
        let out = entry.call(&refs)?;
        println!(
            "{name}: OK ({} inputs, {} outputs, first output {} floats)",
            entry.spec().inputs.len(),
            out.len(),
            out[0].len()
        );
    }
    println!("all artifacts load and execute");
    Ok(())
}

fn cmd_bench_compare(args: &[String]) -> Result<(), CliRunError> {
    let spec = CommandSpec::new(
        "bench-compare",
        "compare a fresh bench dump against a committed BENCH_<suite>.json baseline",
    )
    .positional("baseline", "committed baseline (e.g. BENCH_ingest.json)")
    .positional("current", "freshly generated dump to check")
    .opt(
        "threshold",
        "0.15",
        "allowed relative throughput drop before failing (0.15 = 15%)",
    );
    let p = parse_with(&spec, args)?;
    let load = |idx: usize, role: &str| -> Result<ata::util::json::Json, CliRunError> {
        let path = p
            .positional(idx)
            .ok_or_else(|| format!("bench-compare requires a {role} path"))?;
        let text =
            std::fs::read_to_string(path).map_err(|e| format!("read {path}: {e}"))?;
        ata::util::json::Json::parse(&text)
            .map_err(|e| CliRunError::Fail(format!("parse {path}: {e}")))
    };
    let baseline = load(0, "baseline")?;
    let current = load(1, "current")?;
    let threshold = p.f64("threshold").map_err(|e| e.to_string())?;
    if !(0.0..1.0).contains(&threshold) {
        return Err("--threshold must be in [0, 1)".to_string().into());
    }
    let report = ata::benchkit::compare::compare(&baseline, &current, threshold)?;
    print!("{}", report.render());
    if report.passed() {
        Ok(())
    } else {
        Err(format!(
            "{} throughput regression(s), {} missing figure(s)",
            report.regressions().len(),
            report.missing.len()
        )
        .into())
    }
}

fn cmd_weights(args: &[String]) -> Result<(), CliRunError> {
    let spec = CommandSpec::new(
        "weights",
        "reconstruct an averager's weight profile and staleness report",
    )
    .req("spec", "averager spec, e.g. 'awa3(c=0.5)'")
    .opt("t", "200", "stream length");
    let p = parse_with(&spec, args)?;
    let aspec = AveragerSpec::parse(&p.str("spec"))?;
    let t = p.u64("t").map_err(|e| e.to_string())?;
    let k_t = match &aspec {
        AveragerSpec::ExpK { k } => *k as f64,
        AveragerSpec::Exp { gamma } => (1.0 + gamma) / (1.0 - gamma),
        AveragerSpec::Gea { c } | AveragerSpec::Raw { c, .. } => c * t as f64,
        AveragerSpec::Awa { window, .. }
        | AveragerSpec::True { window }
        | AveragerSpec::Restart { window }
        | AveragerSpec::Eh { window, .. } => window.k_at(t),
        AveragerSpec::TwoTail { .. } => {
            // twotail's weights are data-dependent (it switches tails on the
            // observed variance), so there is no fixed profile to replay a
            // unit impulse through.
            let msg = "twotail has no fixed weight profile: the selected tail \
                       is data-dependent; query the live stream's ess/window \
                       via `ata query` instead";
            return Err(msg.to_string().into());
        }
    };
    let r = staleness_report(&aspec, t, k_t)?;
    println!("spec             : {}", aspec.label());
    println!("stream length t  : {t}");
    println!("nominal window   : {k_t:.2}");
    println!("weight sum       : {:.9}", r.weight_sum);
    println!("variance Σα²     : {:.6e}", r.variance);
    println!("effective samples: {:.2}", r.effective_samples);
    println!("mean age         : {:.2}", r.mean_age);
    println!("max age          : {}", r.max_age);
    println!("stale mass (>k_t): {:.4}", r.stale_mass);
    Ok(())
}
