//! Lightweight metrics: counters, gauges, log-bucketed histograms and a
//! named registry with JSON export (scraped by the coordinator service's
//! `metrics` command and printed by the benches).
//!
//! All instruments are lock-free (`AtomicU64`) so they can sit on the
//! coordinator's hot path; floats are stored as bit patterns.

use crate::util::cpu::CachePadded;
use crate::util::json::Json;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Canonical instrument names shared by the coordinator and the
/// persist subsystem, so the stats endpoint, the benches and the docs
/// all agree on spelling. All four appear under `counter.*` in
/// [`Registry::export`] (scraped by the service's `metrics` op):
///
/// * [`names::WAL_APPENDED_BYTES`] — total framed bytes appended across
///   every shard's WAL.
/// * [`names::WAL_FSYNC_NANOS`] — cumulative nanoseconds spent in WAL
///   `fsync` (per-append when `persist.fsync`, plus segment rotations).
/// * [`names::CHECKPOINT_DURATION_NANOS`] — cumulative nanoseconds of
///   completed checkpoints (quiesce + encode + atomic write + WAL
///   truncation).
/// * [`names::RECOVERY_REPLAYED_BATCHES`] — WAL records re-applied by
///   `Coordinator::recover` after loading the snapshot.
///
/// The wire layer adds its own family (recorded by the TCP server into
/// the coordinator's registry):
///
/// * [`names::CONNECTIONS_V1`] / [`names::CONNECTIONS_V2`] —
///   connections by negotiated protocol generation (auto-detected
///   legacy JSON peers count under v1).
/// * [`names::FRAMES_IN`] / [`names::FRAMES_OUT`] — wire frames read
///   from / written to peers.
/// * [`names::OVERSIZED_RESPONSES`] — responses that exceeded
///   `MAX_FRAME` and were replaced by a structured error frame instead
///   of being written (which would have killed the peer's read loop).
/// * [`names::MULTI_PUSH_ENTRIES`] — per-stream batches staged through
///   the v2 `multi_push` fan-in op.
///
/// The analytics layer adds its own family:
///
/// * [`names::STAT_QUERIES`] — per-stream stat snapshots computed
///   (every `stat_snapshot`/`multi_snapshot` entry and every stream a
///   `query` read).
/// * [`names::MULTI_SNAPSHOT_ENTRIES`] — entries carried by
///   `multi_snapshot` fan-in frames.
/// * [`names::QUERY_STREAMS_MATCHED`] — streams matched by `query`
///   prefix selections.
pub mod names {
    pub const WAL_APPENDED_BYTES: &str = "wal_appended_bytes";
    pub const WAL_FSYNC_NANOS: &str = "wal_fsync_nanos";
    /// Group commits executed (one fsync each) when
    /// `persist.group_commit_micros > 0`.
    pub const WAL_GROUP_COMMITS: &str = "wal_group_commits";
    /// Appends amortized across those group commits (group size =
    /// `wal_group_appends / wal_group_commits`).
    pub const WAL_GROUP_APPENDS: &str = "wal_group_appends";
    /// Cumulative nanoseconds appends spent waiting dirty before their
    /// group's fsync landed (commit stall).
    pub const WAL_GROUP_STALL_NANOS: &str = "wal_group_stall_nanos";
    /// Buffer-pool takes served by a recycled allocation vs fresh ones.
    /// All three surface as `gauge.*` — the pools account internally and
    /// `Coordinator::export_metrics` refreshes the gauges at scrape
    /// time; `pool_reuse_ratio` is hits / (hits + misses).
    pub const POOL_HITS: &str = "pool_hits";
    pub const POOL_MISSES: &str = "pool_misses";
    pub const POOL_REUSE_RATIO: &str = "pool_reuse_ratio";
    pub const CHECKPOINT_DURATION_NANOS: &str = "checkpoint_duration_nanos";
    pub const RECOVERY_REPLAYED_BATCHES: &str = "recovery_replayed_batches";
    pub const CONNECTIONS_V1: &str = "wire_connections_v1";
    pub const CONNECTIONS_V2: &str = "wire_connections_v2";
    pub const FRAMES_IN: &str = "wire_frames_in";
    pub const FRAMES_OUT: &str = "wire_frames_out";
    pub const OVERSIZED_RESPONSES: &str = "wire_oversized_responses";
    pub const MULTI_PUSH_ENTRIES: &str = "multi_push_entries";
    pub const STAT_QUERIES: &str = "stat_queries";
    pub const MULTI_SNAPSHOT_ENTRIES: &str = "multi_snapshot_entries";
    pub const QUERY_STREAMS_MATCHED: &str = "query_streams_matched";
    /// Shard workers restarted by the supervisor after a panic.
    pub const SHARD_RESTARTS: &str = "shard_restarts";
    /// In-flight batches dropped (quarantined) because their apply
    /// panicked; each is also attributed to its stream for the
    /// poison-stream policy.
    pub const QUARANTINED_BATCHES: &str = "quarantined_batches";
    /// Streams isolated by the poison-stream policy after repeatedly
    /// killing their shard worker.
    pub const POISONED_STREAMS: &str = "poisoned_streams";
    /// Samples refused (policy `reject`) or silently skipped (policy
    /// `ignore`) because they contained a NaN/Inf component.
    pub const NON_FINITE_REJECTED: &str = "non_finite_rejected";
    /// Connections refused by the `max_connections` admission gate.
    pub const CONNECTIONS_REJECTED: &str = "wire_connections_rejected";
    /// Connections closed because a read deadline or idle timeout
    /// expired.
    pub const DEADLINE_CLOSES: &str = "wire_deadline_closes";
    /// Structured `Overloaded` responses returned to peers (reject
    /// backpressure policy or drain refusals).
    pub const OVERLOADED_RESPONSES: &str = "wire_overloaded_responses";
    /// Requests whose span was sampled into the per-stage latency
    /// histograms (see `obs`; rate set by `obs.sample_per_mille`).
    pub const TRACE_SPANS_SAMPLED: &str = "trace_spans_sampled";
    /// Sampled spans that completed all six stages and were retired
    /// into the recent-span log.
    pub const TRACE_SPANS_COMPLETED: &str = "trace_spans_completed";
    /// Flight-recorder events recorded across all shard rings.
    pub const FLIGHT_EVENTS: &str = "flight_events";
    /// Deepest shard queue observed at the last drain boundary /
    /// metrics refresh (`gauge.*`).
    pub const QUEUE_DEPTH_MAX: &str = "queue_depth_max";
    /// Total samples waiting in shard queues at the last refresh
    /// (`gauge.*`).
    pub const QUEUE_DEPTH_TOTAL: &str = "queue_depth_total";
    /// Rows currently resident across all banks (`gauge.*`), refreshed
    /// at drain boundaries and by `Coordinator::export_metrics`.
    pub const BANK_ROWS: &str = "bank_rows";
    /// Version of the newest cluster ring this node has adopted
    /// (`gauge.*`; 0 = not federated). Bumped by `cluster_hello`.
    pub const CLUSTER_RING_VERSION: &str = "cluster_ring_version";
    /// Raw WAL bytes the replication shipper has streamed to the
    /// standby (acknowledged appends only).
    pub const WAL_SHIPPED_BYTES: &str = "wal_shipped_bytes";
    /// Committed-but-unshipped WAL bytes at the last ship cycle
    /// (`gauge.*`) — the standby's worst-case failover loss.
    pub const WAL_SHIP_LAG_BYTES: &str = "wal_ship_lag_bytes";
    /// Failovers executed: a standby promoted into a dead node's slot
    /// (counted on the node driving the ring update).
    pub const CLUSTER_FAILOVERS: &str = "cluster_failovers";
}

/// Monotone event counter. The atomic is padded to its own cache line:
/// counters are handed out as individual `Arc`s and bumped from
/// different shard workers, so two hot counters packed into one line by
/// the allocator would false-share on every increment.
#[derive(Default)]
pub struct Counter {
    value: CachePadded<AtomicU64>,
}

impl Counter {
    pub fn new() -> Counter {
        Counter::default()
    }

    #[inline]
    pub fn inc(&self) {
        self.add(1);
    }

    #[inline]
    pub fn add(&self, n: u64) {
        self.value.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.value.load(Ordering::Relaxed)
    }
}

/// Last-write-wins gauge holding an `f64`, cache-line padded like
/// [`Counter`] (same shared-`Arc`, cross-thread write pattern).
#[derive(Default)]
pub struct Gauge {
    bits: CachePadded<AtomicU64>,
}

impl Gauge {
    pub fn new() -> Gauge {
        Gauge::default()
    }

    #[inline]
    pub fn set(&self, v: f64) {
        self.bits.store(v.to_bits(), Ordering::Relaxed);
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.bits.load(Ordering::Relaxed))
    }
}

/// Histogram with logarithmic buckets covering `[1ns, ~18s]` when used
/// for nanosecond latencies (factor-2 buckets, 64 of them) — O(1) record,
/// approximate quantiles.
pub struct Histogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram::new()
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram {
            buckets: (0..64).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
        }
    }

    /// Record a nonnegative value (values < 1 land in bucket 0).
    #[inline]
    pub fn record(&self, v: u64) {
        let idx = (64 - v.max(1).leading_zeros() as usize).saturating_sub(1);
        self.buckets[idx.min(63)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            return f64::NAN;
        }
        self.sum.load(Ordering::Relaxed) as f64 / c as f64
    }

    /// Sum of all recorded values (saturating semantics are fine for
    /// latency totals; wraps only after ~580 years of nanoseconds).
    pub fn sum(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }

    /// Snapshot the raw bucket counts. Bucket `i` covers values in
    /// `[2^i, 2^(i+1))` (bucket 0 also absorbs values < 1). Used by the
    /// Prometheus renderer to emit cumulative `le` buckets.
    pub fn bucket_counts(&self) -> Vec<u64> {
        self.buckets
            .iter()
            .map(|b| b.load(Ordering::Relaxed))
            .collect()
    }

    /// Approximate quantile `q ∈ [0,1]`: returns the geometric midpoint of
    /// the bucket containing the q-th sample.
    pub fn quantile(&self, q: f64) -> f64 {
        let total = self.count();
        if total == 0 {
            return f64::NAN;
        }
        let target = ((total as f64) * q.clamp(0.0, 1.0)).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                let lo = 1u64 << i;
                let hi = lo << 1;
                return ((lo as f64) * (hi as f64)).sqrt();
            }
        }
        f64::NAN
    }
}

/// Named instruments, shareable across threads.
#[derive(Clone, Default)]
pub struct Registry {
    inner: Arc<RegistryInner>,
}

#[derive(Default)]
struct RegistryInner {
    counters: Mutex<BTreeMap<String, Arc<Counter>>>,
    gauges: Mutex<BTreeMap<String, Arc<Gauge>>>,
    histograms: Mutex<BTreeMap<String, Arc<Histogram>>>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    /// Get or create a counter.
    pub fn counter(&self, name: &str) -> Arc<Counter> {
        let mut m = self.inner.counters.lock().expect("metrics lock");
        m.entry(name.to_string())
            .or_insert_with(|| Arc::new(Counter::new()))
            .clone()
    }

    /// Get or create a gauge.
    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        let mut m = self.inner.gauges.lock().expect("metrics lock");
        m.entry(name.to_string())
            .or_insert_with(|| Arc::new(Gauge::new()))
            .clone()
    }

    /// Get or create a histogram.
    pub fn histogram(&self, name: &str) -> Arc<Histogram> {
        let mut m = self.inner.histograms.lock().expect("metrics lock");
        m.entry(name.to_string())
            .or_insert_with(|| Arc::new(Histogram::new()))
            .clone()
    }

    /// Snapshot all instruments as JSON.
    pub fn export(&self) -> Json {
        let mut obj = BTreeMap::new();
        {
            let m = self.inner.counters.lock().expect("metrics lock");
            for (k, v) in m.iter() {
                obj.insert(format!("counter.{k}"), Json::Num(v.get() as f64));
            }
        }
        {
            let m = self.inner.gauges.lock().expect("metrics lock");
            for (k, v) in m.iter() {
                obj.insert(format!("gauge.{k}"), Json::Num(v.get()));
            }
        }
        {
            let m = self.inner.histograms.lock().expect("metrics lock");
            for (k, v) in m.iter() {
                obj.insert(
                    format!("hist.{k}"),
                    Json::obj(vec![
                        ("count", Json::Num(v.count() as f64)),
                        ("mean", Json::Num(v.mean())),
                        ("p50", Json::Num(v.quantile(0.5))),
                        ("p90", Json::Num(v.quantile(0.9))),
                        ("p99", Json::Num(v.quantile(0.99))),
                        ("p999", Json::Num(v.quantile(0.999))),
                    ]),
                );
            }
        }
        Json::Obj(obj)
    }

    /// Snapshot every counter as `(name, value)`, sorted by name.
    pub fn counters_snapshot(&self) -> Vec<(String, u64)> {
        let m = self.inner.counters.lock().expect("metrics lock");
        m.iter().map(|(k, v)| (k.clone(), v.get())).collect()
    }

    /// Snapshot every gauge as `(name, value)`, sorted by name.
    pub fn gauges_snapshot(&self) -> Vec<(String, f64)> {
        let m = self.inner.gauges.lock().expect("metrics lock");
        m.iter().map(|(k, v)| (k.clone(), v.get())).collect()
    }

    /// Snapshot every histogram as `(name, handle)`, sorted by name.
    /// Handles are `Arc`s, so callers read bucket counts without
    /// holding the registry lock.
    pub fn histograms_snapshot(&self) -> Vec<(String, Arc<Histogram>)> {
        let m = self.inner.histograms.lock().expect("metrics lock");
        m.iter().map(|(k, v)| (k.clone(), v.clone())).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn counter_concurrent_increments() {
        let reg = Registry::new();
        let mut handles = Vec::new();
        for _ in 0..8 {
            let reg = reg.clone();
            handles.push(thread::spawn(move || {
                let c = reg.counter("events");
                for _ in 0..1000 {
                    c.inc();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(reg.counter("events").get(), 8000);
    }

    #[test]
    fn gauge_set_get() {
        let g = Gauge::new();
        g.set(3.25);
        assert_eq!(g.get(), 3.25);
        g.set(-1.5);
        assert_eq!(g.get(), -1.5);
    }

    #[test]
    fn histogram_quantiles_bucket_accurate() {
        let h = Histogram::new();
        for v in [10u64, 20, 40, 80, 1000, 2000, 100_000] {
            h.record(v);
        }
        assert_eq!(h.count(), 7);
        let p50 = h.quantile(0.5);
        // 4th of 7 sorted values is 80 → bucket [64,128), geo-mid ≈ 90.5
        assert!(p50 > 60.0 && p50 < 130.0, "p50={p50}");
        let p99 = h.quantile(0.99);
        assert!(p99 > 65_000.0 && p99 < 190_000.0, "p99={p99}");
        assert!((h.mean() - 14735.7).abs() < 1.0);
    }

    #[test]
    fn histogram_empty_is_nan() {
        let h = Histogram::new();
        assert!(h.quantile(0.5).is_nan());
        assert!(h.mean().is_nan());
    }

    #[test]
    fn histogram_extremes() {
        let h = Histogram::new();
        h.record(0); // clamps into bucket 0
        h.record(u64::MAX);
        assert_eq!(h.count(), 2);
        assert!(h.quantile(0.0) >= 1.0);
    }

    #[test]
    fn histogram_quantiles_vs_sorted_oracle() {
        // Bucket-accuracy contract: for factor-2 buckets, the reported
        // quantile must fall within [oracle/2, oracle*2] of the exact
        // sorted-sample quantile (bucket midpoint vs any member of the
        // same bucket is at most one octave apart).
        use crate::rng::{RngCore, SplitMix64};
        let mut rng = SplitMix64::new(0xA17A);
        let h = Histogram::new();
        let mut samples = Vec::with_capacity(10_000);
        for _ in 0..10_000 {
            // Log-uniform over roughly [1, 2^40): a skewed latency shape.
            let v = 1u64 << (rng.next_u64() % 40);
            let v = v + rng.next_u64() % v.max(1);
            h.record(v);
            samples.push(v);
        }
        samples.sort_unstable();
        for q in [0.5, 0.9, 0.99, 0.999] {
            let got = h.quantile(q);
            let idx = (((samples.len() as f64) * q).ceil() as usize)
                .max(1)
                .min(samples.len())
                - 1;
            let oracle = samples[idx] as f64;
            assert!(
                got >= oracle / 2.0 && got <= oracle * 2.0,
                "q={q}: got {got}, oracle {oracle}"
            );
        }
        let exact_mean = samples.iter().map(|&v| v as f64).sum::<f64>() / samples.len() as f64;
        assert!((h.mean() - exact_mean).abs() < 1e-6 * exact_mean.max(1.0));
    }

    #[test]
    fn bucket_counts_snapshot_matches_records() {
        let h = Histogram::new();
        h.record(1); // bucket 0
        h.record(3); // bucket 1
        h.record(3); // bucket 1
        let buckets = h.bucket_counts();
        assert_eq!(buckets.len(), 64);
        assert_eq!(buckets[0], 1);
        assert_eq!(buckets[1], 2);
        assert_eq!(buckets.iter().sum::<u64>(), h.count());
        assert_eq!(h.sum(), 7);
    }

    #[test]
    fn registry_snapshots_enumerate_everything() {
        let reg = Registry::new();
        reg.counter("b_ctr").inc();
        reg.counter("a_ctr").add(2);
        reg.gauge("g").set(4.5);
        reg.histogram("h").record(9);
        let counters = reg.counters_snapshot();
        assert_eq!(
            counters,
            vec![("a_ctr".to_string(), 2), ("b_ctr".to_string(), 1)]
        );
        assert_eq!(reg.gauges_snapshot(), vec![("g".to_string(), 4.5)]);
        let hists = reg.histograms_snapshot();
        assert_eq!(hists.len(), 1);
        assert_eq!(hists[0].0, "h");
        assert_eq!(hists[0].1.count(), 1);
    }

    #[test]
    fn registry_same_instrument_shared() {
        let reg = Registry::new();
        reg.counter("x").add(5);
        reg.counter("x").add(7);
        assert_eq!(reg.counter("x").get(), 12);
    }

    #[test]
    fn export_contains_everything() {
        let reg = Registry::new();
        reg.counter("pushes").add(3);
        reg.gauge("depth").set(1.5);
        reg.histogram("lat").record(100);
        let j = reg.export();
        assert_eq!(j.get("counter.pushes").and_then(Json::as_f64), Some(3.0));
        assert_eq!(j.get("gauge.depth").and_then(Json::as_f64), Some(1.5));
        assert!(j.get("hist.lat").is_some());
        // Export must be valid JSON text.
        assert!(Json::parse(&j.encode()).is_ok());
    }
}
