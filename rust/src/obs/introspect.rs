//! The `introspect` wire op's payload: a structured point-in-time
//! report of the coordinator's moving parts — per-shard queue depth and
//! worker churn, per-bank occupancy, per-stream health, plus the most
//! recent flight-recorder events and retired trace spans.
//!
//! The report has two codecs, mirroring the protocol split: a compact
//! binary form on the persist `Enc`/`Dec` primitives (v2) and a JSON
//! form (v1). Both round-trip losslessly; handles and trace ids travel
//! as decimal strings in JSON because they exceed 2^53.

use crate::obs::recorder::Event;
use crate::obs::SpanRecord;
use crate::obs::STAGES;
use crate::persist::codec::{Dec, Enc};
use crate::util::json::Json;

/// One shard worker's vitals.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ShardReport {
    pub shard: u16,
    /// Batches sitting in the shard queue right now.
    pub queue_depth: u64,
    /// Worker incarnations (1 = never restarted; each panic adds one).
    pub worker_starts: u64,
    /// WAL write position at the last drain boundary (0/0 = no WAL).
    pub wal_segment: u64,
    pub wal_offset: u64,
    /// WAL position recovery replayed from at boot (0/0 = fresh boot or
    /// no WAL). Together with the live write position this makes
    /// replica/standby lag observable: a warm standby's shipped bytes
    /// can be compared against `wal_segment`/`wal_offset` here.
    pub wal_replay_segment: u64,
    pub wal_replay_offset: u64,
    /// Flight-recorder events since boot (not capped by ring capacity).
    pub events_recorded: u64,
}

/// One planar bank's occupancy.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BankReport {
    pub index: u64,
    pub dim: u64,
    /// Live rows (registered streams backed by this bank).
    pub rows: u64,
    /// f64 slots per row (dim × accumulators).
    pub row_floats: u64,
}

/// One stream's health counters.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct StreamReport {
    pub name: String,
    pub handle: u64,
    pub dropped: u64,
    pub strikes: u64,
    pub poisoned: bool,
}

/// The full introspection snapshot.
#[derive(Clone, Debug, PartialEq)]
pub struct IntrospectReport {
    /// Current trace sampling rate (per-mille).
    pub sample_per_mille: u32,
    /// Corrupt non-final WAL segment tails skipped by the last recovery
    /// (`RecoveryReport.wal_skipped_tails`, previously only reachable
    /// from the recovery return value). Non-zero means the WAL lost
    /// records mid-history at boot — worth an operator's attention.
    pub wal_skipped_tails: u64,
    pub shards: Vec<ShardReport>,
    pub banks: Vec<BankReport>,
    pub streams: Vec<StreamReport>,
    /// Most recent flight-recorder events across all shards, merged and
    /// time-ordered, newest last (bounded by the requested limit).
    pub events: Vec<Event>,
    /// Most recent retired trace spans, oldest first.
    pub spans: Vec<SpanRecord>,
}

/// Hostile-count guard: a decoded element count must be plausible for
/// the bytes actually remaining (`min_len` bytes per element), so a
/// forged count cannot drive a huge allocation before the decode fails.
fn checked_count(dec: &Dec<'_>, count: usize, min_len: usize) -> Result<usize, String> {
    if count.saturating_mul(min_len) > dec.remaining() {
        return Err(format!(
            "introspect: count {count} needs at least {} bytes, {} remain",
            count.saturating_mul(min_len),
            dec.remaining()
        ));
    }
    Ok(count)
}

impl IntrospectReport {
    /// Binary form (the v2 codec): sections in struct order, each a
    /// `u32` count followed by fixed-layout records.
    pub fn encode(&self, enc: &mut Enc) {
        enc.put_u32(self.sample_per_mille);
        enc.put_u64(self.wal_skipped_tails);
        enc.put_u32(self.shards.len() as u32);
        for s in &self.shards {
            enc.put_u16(s.shard);
            enc.put_u64(s.queue_depth);
            enc.put_u64(s.worker_starts);
            enc.put_u64(s.wal_segment);
            enc.put_u64(s.wal_offset);
            enc.put_u64(s.wal_replay_segment);
            enc.put_u64(s.wal_replay_offset);
            enc.put_u64(s.events_recorded);
        }
        enc.put_u32(self.banks.len() as u32);
        for b in &self.banks {
            enc.put_u64(b.index);
            enc.put_u64(b.dim);
            enc.put_u64(b.rows);
            enc.put_u64(b.row_floats);
        }
        enc.put_u32(self.streams.len() as u32);
        for s in &self.streams {
            enc.put_str(&s.name);
            enc.put_u64(s.handle);
            enc.put_u64(s.dropped);
            enc.put_u64(s.strikes);
            enc.put_u8(s.poisoned as u8);
        }
        enc.put_u32(self.events.len() as u32);
        for e in &self.events {
            e.encode(enc);
        }
        enc.put_u32(self.spans.len() as u32);
        for sp in &self.spans {
            enc.put_u64(sp.trace_id);
            for ns in sp.stage_ns {
                enc.put_u64(ns);
            }
        }
    }

    /// Decode the binary form; errors (never panics) on truncation,
    /// forged counts, or unknown event kinds.
    pub fn decode(dec: &mut Dec<'_>) -> Result<IntrospectReport, String> {
        let sample_per_mille = dec.get_u32()?;
        let wal_skipped_tails = dec.get_u64()?;
        let n = checked_count(dec, dec.get_u32()? as usize, 58)?;
        let mut shards = Vec::with_capacity(n);
        for _ in 0..n {
            shards.push(ShardReport {
                shard: dec.get_u16()?,
                queue_depth: dec.get_u64()?,
                worker_starts: dec.get_u64()?,
                wal_segment: dec.get_u64()?,
                wal_offset: dec.get_u64()?,
                wal_replay_segment: dec.get_u64()?,
                wal_replay_offset: dec.get_u64()?,
                events_recorded: dec.get_u64()?,
            });
        }
        let n = checked_count(dec, dec.get_u32()? as usize, 32)?;
        let mut banks = Vec::with_capacity(n);
        for _ in 0..n {
            banks.push(BankReport {
                index: dec.get_u64()?,
                dim: dec.get_u64()?,
                rows: dec.get_u64()?,
                row_floats: dec.get_u64()?,
            });
        }
        let n = checked_count(dec, dec.get_u32()? as usize, 29)?;
        let mut streams = Vec::with_capacity(n);
        for _ in 0..n {
            streams.push(StreamReport {
                name: dec.get_str()?,
                handle: dec.get_u64()?,
                dropped: dec.get_u64()?,
                strikes: dec.get_u64()?,
                poisoned: dec.get_u8()? != 0,
            });
        }
        let n = checked_count(
            dec,
            dec.get_u32()? as usize,
            crate::obs::recorder::EVENT_ENCODED_LEN,
        )?;
        let mut events = Vec::with_capacity(n);
        for _ in 0..n {
            events.push(Event::decode(dec)?);
        }
        let n = checked_count(dec, dec.get_u32()? as usize, 8 * (1 + STAGES))?;
        let mut spans = Vec::with_capacity(n);
        for _ in 0..n {
            let trace_id = dec.get_u64()?;
            let mut stage_ns = [0u64; STAGES];
            for ns in &mut stage_ns {
                *ns = dec.get_u64()?;
            }
            spans.push(SpanRecord { trace_id, stage_ns });
        }
        Ok(IntrospectReport {
            sample_per_mille,
            wal_skipped_tails,
            shards,
            banks,
            streams,
            events,
            spans,
        })
    }

    /// JSON form (the v1 codec). Handles and trace ids are decimal
    /// strings: they exceed 2^53 and would shear in an f64.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("sample_per_mille", Json::Num(self.sample_per_mille as f64)),
            (
                "wal_skipped_tails",
                Json::Num(self.wal_skipped_tails as f64),
            ),
            (
                "shards",
                Json::Arr(
                    self.shards
                        .iter()
                        .map(|s| {
                            Json::obj(vec![
                                ("shard", Json::Num(s.shard as f64)),
                                ("queue_depth", Json::Num(s.queue_depth as f64)),
                                ("worker_starts", Json::Num(s.worker_starts as f64)),
                                ("wal_segment", Json::Num(s.wal_segment as f64)),
                                ("wal_offset", Json::Num(s.wal_offset as f64)),
                                (
                                    "wal_replay_segment",
                                    Json::Num(s.wal_replay_segment as f64),
                                ),
                                (
                                    "wal_replay_offset",
                                    Json::Num(s.wal_replay_offset as f64),
                                ),
                                ("events_recorded", Json::Num(s.events_recorded as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "banks",
                Json::Arr(
                    self.banks
                        .iter()
                        .map(|b| {
                            Json::obj(vec![
                                ("index", Json::Num(b.index as f64)),
                                ("dim", Json::Num(b.dim as f64)),
                                ("rows", Json::Num(b.rows as f64)),
                                ("row_floats", Json::Num(b.row_floats as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "streams",
                Json::Arr(
                    self.streams
                        .iter()
                        .map(|s| {
                            Json::obj(vec![
                                ("name", Json::Str(s.name.clone())),
                                ("handle", Json::Str(s.handle.to_string())),
                                ("dropped", Json::Num(s.dropped as f64)),
                                ("strikes", Json::Num(s.strikes as f64)),
                                ("poisoned", Json::Bool(s.poisoned)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "events",
                Json::Arr(
                    self.events
                        .iter()
                        .map(|e| {
                            Json::obj(vec![
                                ("kind", Json::Str(e.kind.label().to_string())),
                                ("shard", Json::Num(e.shard as f64)),
                                ("trace_id", Json::Str(e.trace_id.to_string())),
                                ("handle", Json::Str(e.handle.to_string())),
                                ("arg", Json::Num(e.arg as f64)),
                                ("at_nanos", Json::Num(e.at_nanos as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "spans",
                Json::Arr(
                    self.spans
                        .iter()
                        .map(|sp| {
                            Json::obj(vec![
                                ("trace_id", Json::Str(sp.trace_id.to_string())),
                                (
                                    "stage_ns",
                                    Json::Arr(
                                        sp.stage_ns
                                            .iter()
                                            .map(|&ns| Json::Num(ns as f64))
                                            .collect(),
                                    ),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Parse the JSON form; tolerant of field order, strict on shape.
    pub fn from_json(j: &Json) -> Result<IntrospectReport, String> {
        let sample_per_mille = j
            .get("sample_per_mille")
            .and_then(Json::as_u64)
            .ok_or("introspect: missing sample_per_mille")? as u32;
        let wal_skipped_tails = num(j, "wal_skipped_tails")?;
        let mut shards = Vec::new();
        for s in arr(j, "shards")? {
            shards.push(ShardReport {
                shard: num(s, "shard")? as u16,
                queue_depth: num(s, "queue_depth")?,
                worker_starts: num(s, "worker_starts")?,
                wal_segment: num(s, "wal_segment")?,
                wal_offset: num(s, "wal_offset")?,
                wal_replay_segment: num(s, "wal_replay_segment")?,
                wal_replay_offset: num(s, "wal_replay_offset")?,
                events_recorded: num(s, "events_recorded")?,
            });
        }
        let mut banks = Vec::new();
        for b in arr(j, "banks")? {
            banks.push(BankReport {
                index: num(b, "index")?,
                dim: num(b, "dim")?,
                rows: num(b, "rows")?,
                row_floats: num(b, "row_floats")?,
            });
        }
        let mut streams = Vec::new();
        for s in arr(j, "streams")? {
            streams.push(StreamReport {
                name: s
                    .get("name")
                    .and_then(Json::as_str)
                    .ok_or("introspect: stream missing name")?
                    .to_string(),
                handle: id64(s, "handle")?,
                dropped: num(s, "dropped")?,
                strikes: num(s, "strikes")?,
                poisoned: s.get("poisoned").and_then(Json::as_bool).unwrap_or(false),
            });
        }
        let mut events = Vec::new();
        for e in arr(j, "events")? {
            let label = e
                .get("kind")
                .and_then(Json::as_str)
                .ok_or("introspect: event missing kind")?;
            let kind = kind_of(label)?;
            events.push(Event {
                kind,
                shard: num(e, "shard")? as u16,
                trace_id: id64(e, "trace_id")?,
                handle: id64(e, "handle")?,
                arg: num(e, "arg")?,
                at_nanos: num(e, "at_nanos")?,
            });
        }
        let mut spans = Vec::new();
        for sp in arr(j, "spans")? {
            let ns_arr = sp
                .get("stage_ns")
                .and_then(Json::as_arr)
                .ok_or("introspect: span missing stage_ns")?;
            if ns_arr.len() != STAGES {
                return Err(format!(
                    "introspect: span has {} stages, expected {STAGES}",
                    ns_arr.len()
                ));
            }
            let mut stage_ns = [0u64; STAGES];
            for (slot, v) in stage_ns.iter_mut().zip(ns_arr) {
                *slot = v.as_u64().ok_or("introspect: bad stage_ns entry")?;
            }
            spans.push(SpanRecord {
                trace_id: id64(sp, "trace_id")?,
                stage_ns,
            });
        }
        Ok(IntrospectReport {
            sample_per_mille,
            wal_skipped_tails,
            shards,
            banks,
            streams,
            events,
            spans,
        })
    }
}

fn arr<'a>(j: &'a Json, key: &str) -> Result<&'a [Json], String> {
    j.get(key)
        .and_then(Json::as_arr)
        .ok_or_else(|| format!("introspect: missing array '{key}'"))
}

fn num(j: &Json, key: &str) -> Result<u64, String> {
    j.get(key)
        .and_then(Json::as_u64)
        .ok_or_else(|| format!("introspect: missing number '{key}'"))
}

/// A u64 id that may arrive as a decimal string (canonical — survives
/// f64 shearing) or, from lenient peers, a plain number.
fn id64(j: &Json, key: &str) -> Result<u64, String> {
    match j.get(key) {
        Some(Json::Str(s)) => s
            .parse::<u64>()
            .map_err(|_| format!("introspect: bad id in '{key}'")),
        Some(v) => v
            .as_u64()
            .ok_or_else(|| format!("introspect: bad id in '{key}'")),
        None => Err(format!("introspect: missing id '{key}'")),
    }
}

fn kind_of(label: &str) -> Result<crate::obs::recorder::EventKind, String> {
    use crate::obs::recorder::EventKind;
    for k in [
        EventKind::Push,
        EventKind::Drop,
        EventKind::Quarantine,
        EventKind::Poison,
        EventKind::Overload,
        EventKind::WalRotation,
        EventKind::Checkpoint,
        EventKind::WalShip,
        EventKind::RingUpdate,
    ] {
        if k.label() == label {
            return Ok(k);
        }
    }
    Err(format!("introspect: unknown event kind '{label}'"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::recorder::EventKind;

    fn sample() -> IntrospectReport {
        IntrospectReport {
            sample_per_mille: 10,
            wal_skipped_tails: 1,
            shards: vec![
                ShardReport {
                    shard: 0,
                    queue_depth: 3,
                    worker_starts: 1,
                    wal_segment: 2,
                    wal_offset: 4096,
                    wal_replay_segment: 1,
                    wal_replay_offset: 262,
                    events_recorded: 77,
                },
                ShardReport {
                    shard: 1,
                    queue_depth: 0,
                    worker_starts: 4,
                    wal_segment: 0,
                    wal_offset: 0,
                    wal_replay_segment: 0,
                    wal_replay_offset: 0,
                    events_recorded: 0,
                },
            ],
            banks: vec![BankReport {
                index: 0,
                dim: 8,
                rows: 12,
                row_floats: 48,
            }],
            streams: vec![StreamReport {
                name: "grad".into(),
                handle: u64::MAX - 3,
                dropped: 9,
                strikes: 2,
                poisoned: true,
            }],
            events: vec![Event {
                kind: EventKind::Quarantine,
                shard: 1,
                trace_id: u64::MAX - 1,
                handle: u64::MAX - 3,
                arg: 2,
                at_nanos: 123_456,
            }],
            spans: vec![SpanRecord {
                trace_id: u64::MAX - 1,
                stage_ns: [1, 2, 3, 4, 5, 6],
            }],
        }
    }

    #[test]
    fn binary_roundtrip() {
        let r = sample();
        let mut enc = Enc::new();
        r.encode(&mut enc);
        let bytes = enc.into_bytes();
        let mut dec = Dec::new(&bytes);
        let got = IntrospectReport::decode(&mut dec).unwrap();
        assert_eq!(got, r);
        assert_eq!(dec.remaining(), 0);
    }

    #[test]
    fn json_roundtrip_preserves_wide_ids() {
        let r = sample();
        let text = r.to_json().encode();
        let back = IntrospectReport::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, r, "u64 ids above 2^53 must survive JSON");
        // The wide ids really did travel as strings.
        assert!(text.contains(&format!("\"{}\"", u64::MAX - 3)), "{text}");
    }

    #[test]
    fn hostile_binary_never_panics() {
        let r = sample();
        let mut enc = Enc::new();
        r.encode(&mut enc);
        let bytes = enc.into_bytes();
        // Every truncation errors cleanly.
        for cut in 0..bytes.len() {
            assert!(IntrospectReport::decode(&mut Dec::new(&bytes[..cut])).is_err());
        }
        // A forged section count cannot drive a huge allocation (the
        // shard count sits after sample_per_mille: u32 and
        // wal_skipped_tails: u64).
        let mut forged = bytes.clone();
        forged[12..16].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(IntrospectReport::decode(&mut Dec::new(&forged)).is_err());
    }

    #[test]
    fn empty_report_roundtrips() {
        let r = IntrospectReport {
            sample_per_mille: 0,
            wal_skipped_tails: 0,
            shards: vec![],
            banks: vec![],
            streams: vec![],
            events: vec![],
            spans: vec![],
        };
        let mut enc = Enc::new();
        r.encode(&mut enc);
        let bytes = enc.into_bytes();
        assert_eq!(
            IntrospectReport::decode(&mut Dec::new(&bytes)).unwrap(),
            r
        );
        let back = IntrospectReport::from_json(&r.to_json()).unwrap();
        assert_eq!(back, r);
    }
}
