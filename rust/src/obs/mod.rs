//! Flight-recorder observability: request tracing, per-stage latency
//! histograms, a lock-free event ring, and the introspection plane.
//!
//! The system's contract about *itself* mirrors the paper's contract
//! about the stream: anytime, constant-overhead answers. Everything in
//! this module is always-on and costs one relaxed atomic load on the
//! hot path while disarmed (sample rate 0), exactly like the chaos
//! harness's hooks:
//!
//! * **Tracing** ([`mint_trace_id`], [`Span`]) — every request carries a
//!   `u64` trace id, minted at the client (or at admission for legacy
//!   v1 peers) and echoed in the ack. A *sampled* subset of push
//!   requests additionally records a [`Span`]: six stage latencies
//!   (admission → queue-wait → apply → WAL append → fsync-settle →
//!   ack-write), each costing one `Instant` read when armed.
//! * **Stage histograms** — each recorded stage also lands in a fixed
//!   `stage_latency_<stage>` log-bucketed histogram family in the
//!   metrics registry, exported with p50/p90/p99/p999.
//! * **Flight recorder** ([`recorder::FlightRecorder`]) — a per-shard
//!   fixed-size ring of compact binary events (push/drop/quarantine/
//!   poison/overload/WAL-rotation/checkpoint) with trace id and stream
//!   handle; dumped by the supervisor on panic and snapshottable on
//!   demand through the `introspect` wire op.
//! * **Exposition** ([`prom`]) — Prometheus text-format rendering of
//!   the whole registry, served alongside the JSON `metrics` op.

pub mod introspect;
pub mod prom;
pub mod recorder;

use crate::metrics::{Histogram, Registry};
use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// The six stages a traced push moves through, in pipeline order.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Stage {
    /// Frame decoded → batch handed to the shard queue.
    Admission = 0,
    /// Sat in the shard queue waiting for the drain cycle.
    QueueWait = 1,
    /// Estimator apply (bank row or slot recurrence).
    Apply = 2,
    /// WAL append (framing + write; inline fsync when not grouped).
    WalAppend = 3,
    /// Waited dirty for the WAL group commit's shared fsync.
    FsyncSettle = 4,
    /// Response encode + socket write back to the peer.
    AckWrite = 5,
}

/// Number of span stages.
pub const STAGES: usize = 6;

impl Stage {
    /// All stages in pipeline order.
    pub const ALL: [Stage; STAGES] = [
        Stage::Admission,
        Stage::QueueWait,
        Stage::Apply,
        Stage::WalAppend,
        Stage::FsyncSettle,
        Stage::AckWrite,
    ];

    /// Canonical lowercase name (metric suffix and wire label).
    pub fn name(self) -> &'static str {
        match self {
            Stage::Admission => "admission",
            Stage::QueueWait => "queue_wait",
            Stage::Apply => "apply",
            Stage::WalAppend => "wal_append",
            Stage::FsyncSettle => "fsync_settle",
            Stage::AckWrite => "ack_write",
        }
    }
}

/// Histogram name of one stage's latency family (`stage_latency_apply`…).
pub fn stage_hist_name(stage: Stage) -> String {
    format!("stage_latency_{}", stage.name())
}

static NEXT_TRACE: AtomicU64 = AtomicU64::new(0);

/// Mint a process-unique trace id: time-seeded (SplitMix64 of the boot
/// nanos) so ids from different client processes do not collide in
/// aggregated logs, then sequential — one relaxed `fetch_add` per
/// request. Never returns 0 (the "no trace" sentinel).
pub fn mint_trace_id() -> u64 {
    let mut id = NEXT_TRACE.fetch_add(1, Ordering::Relaxed);
    if id == 0 {
        // First mint in this process: seed the space off the clock.
        let nanos = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_nanos() as u64)
            .unwrap_or(0x9E37_79B9_7F4A_7C15);
        use crate::rng::RngCore as _;
        let seeded = crate::rng::SplitMix64::new(nanos).next_u64() | 1;
        // Racing first-minters both try the swap; losers just use their
        // fetch_add offset from the winner's seed.
        let _ = NEXT_TRACE.compare_exchange(1, seeded, Ordering::Relaxed, Ordering::Relaxed);
        id = NEXT_TRACE.fetch_add(1, Ordering::Relaxed);
    }
    id.max(1)
}

/// A completed (or in-flight) sampled span: the trace id plus the six
/// stage latencies in nanoseconds. Shared `Arc` between the connection
/// handler (admission, ack-write) and the shard worker (queue-wait,
/// apply, WAL append, fsync-settle); whoever fills the final stage
/// retires it into the span log.
pub struct Span {
    pub trace_id: u64,
    stage_ns: [AtomicU64; STAGES],
    /// Bitmask of filled stages; the span retires at 0b111111.
    filled: AtomicU32,
}

/// Every stage filled.
const ALL_STAGES_MASK: u32 = (1 << STAGES as u32) - 1;

/// A retired span as plain data (the span log / wire form).
#[derive(Clone, Debug, PartialEq)]
pub struct SpanRecord {
    pub trace_id: u64,
    /// Nanoseconds per stage, indexed by [`Stage`] discriminant.
    pub stage_ns: [u64; STAGES],
}

impl Span {
    fn new(trace_id: u64) -> Span {
        Span {
            trace_id,
            stage_ns: Default::default(),
            filled: AtomicU32::new(0),
        }
    }

    /// Nanos recorded for `stage` so far (0 = unfilled).
    pub fn stage_nanos(&self, stage: Stage) -> u64 {
        self.stage_ns[stage as usize].load(Ordering::Relaxed)
    }

    fn snapshot(&self) -> SpanRecord {
        let mut stage_ns = [0u64; STAGES];
        for (i, s) in self.stage_ns.iter().enumerate() {
            stage_ns[i] = s.load(Ordering::Relaxed);
        }
        SpanRecord {
            trace_id: self.trace_id,
            stage_ns,
        }
    }
}

/// Sampling + span bookkeeping + the stage histogram family. One per
/// coordinator, shared (`Arc`) with the server and every shard worker.
pub struct Obs {
    /// Per-mille of push requests that record a span (0 = disarmed,
    /// 1000 = every request).
    sample_per_mille: AtomicU32,
    /// Round-robin sampling cursor (deterministic 1-in-N, not random:
    /// the overhead bound must hold for every request, and a counter is
    /// cheaper than an RNG).
    cursor: AtomicU64,
    /// Spans sampled since boot.
    sampled: Arc<crate::metrics::Counter>,
    /// Spans whose six stages all completed and were retired to the log.
    completed: Arc<crate::metrics::Counter>,
    /// One histogram per stage, indexed by [`Stage`] discriminant, and
    /// registered as `stage_latency_<stage>` so they ride the normal
    /// registry export.
    stage_hists: [Arc<Histogram>; STAGES],
    /// Most recent retired spans (bounded; oldest evicted).
    span_log: Mutex<std::collections::VecDeque<SpanRecord>>,
    span_log_cap: usize,
}

impl Obs {
    /// Build against `registry`, registering the stage histogram family
    /// and the trace counters.
    pub fn new(registry: &Registry, sample_per_mille: u32, span_log_cap: usize) -> Obs {
        let stage_hists = Stage::ALL.map(|s| registry.histogram(&stage_hist_name(s)));
        Obs {
            sample_per_mille: AtomicU32::new(sample_per_mille.min(1000)),
            cursor: AtomicU64::new(0),
            sampled: registry.counter(crate::metrics::names::TRACE_SPANS_SAMPLED),
            completed: registry.counter(crate::metrics::names::TRACE_SPANS_COMPLETED),
            stage_hists,
            span_log: Mutex::new(std::collections::VecDeque::new()),
            span_log_cap: span_log_cap.max(1),
        }
    }

    /// Current sample rate in per-mille.
    pub fn sample_per_mille(&self) -> u32 {
        self.sample_per_mille.load(Ordering::Relaxed)
    }

    /// Change the sample rate at runtime.
    pub fn set_sample_per_mille(&self, per_mille: u32) {
        self.sample_per_mille
            .store(per_mille.min(1000), Ordering::Relaxed);
    }

    /// Decide whether this request records a span. Disarmed cost: ONE
    /// relaxed load. Armed cost: one relaxed `fetch_add` and a compare
    /// (deterministic 1-in-⌈1000/rate⌉ round-robin).
    #[inline]
    pub fn should_sample(&self) -> bool {
        let rate = self.sample_per_mille.load(Ordering::Relaxed);
        if rate == 0 {
            return false;
        }
        if rate >= 1000 {
            return true;
        }
        // Sample when the cursor crosses a multiple of 1000 in rate-steps:
        // exactly `rate` of every 1000 requests, evenly spaced.
        let n = self.cursor.fetch_add(1, Ordering::Relaxed);
        (n.wrapping_mul(rate as u64)) % 1000 < rate as u64
    }

    /// Begin a sampled span for `trace_id`. Call only when
    /// [`Obs::should_sample`] said yes.
    pub fn begin_span(&self, trace_id: u64) -> Arc<Span> {
        self.sampled.inc();
        Arc::new(Span::new(trace_id))
    }

    /// Record `stage` as `elapsed_ns` on `span`: lands in the stage's
    /// histogram, and retires the span to the log when it was the last
    /// unfilled stage. Double-fills keep the first value.
    pub fn record_stage(&self, span: &Arc<Span>, stage: Stage, elapsed_ns: u64) {
        let bit = 1u32 << stage as u32;
        let prev = span.filled.fetch_or(bit, Ordering::AcqRel);
        if prev & bit != 0 {
            return; // already filled (restarted worker re-applying)
        }
        // Clamp to >=1 so "filled with 0ns" stays distinguishable from
        // unfilled in the record.
        span.stage_ns[stage as usize].store(elapsed_ns.max(1), Ordering::Relaxed);
        self.stage_hists[stage as usize].record(elapsed_ns.max(1));
        if prev | bit == ALL_STAGES_MASK {
            self.completed.inc();
            let rec = span.snapshot();
            let mut log = self.span_log.lock().unwrap_or_else(|e| e.into_inner());
            if log.len() >= self.span_log_cap {
                log.pop_front();
            }
            log.push_back(rec);
        }
    }

    /// Convenience: record `stage` as the time since `start`.
    #[inline]
    pub fn record_stage_since(&self, span: &Arc<Span>, stage: Stage, start: Instant) {
        self.record_stage(span, stage, start.elapsed().as_nanos() as u64);
    }

    /// The most recent retired spans, oldest first (bounded by the
    /// configured log capacity; `limit = 0` means all).
    pub fn recent_spans(&self, limit: usize) -> Vec<SpanRecord> {
        let log = self.span_log.lock().unwrap_or_else(|e| e.into_inner());
        let n = if limit == 0 { log.len() } else { limit.min(log.len()) };
        log.iter().skip(log.len() - n).cloned().collect()
    }

    /// One stage histogram (tests and the introspection plane).
    pub fn stage_histogram(&self, stage: Stage) -> &Arc<Histogram> {
        &self.stage_hists[stage as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn obs(rate: u32) -> Obs {
        Obs::new(&Registry::new(), rate, 8)
    }

    #[test]
    fn trace_ids_unique_and_nonzero() {
        let mut seen = std::collections::BTreeSet::new();
        for _ in 0..1000 {
            let id = mint_trace_id();
            assert_ne!(id, 0);
            assert!(seen.insert(id), "duplicate trace id {id}");
        }
    }

    #[test]
    fn sampling_rates() {
        assert!(!obs(0).should_sample());
        let all = obs(1000);
        assert!((0..100).all(|_| all.should_sample()));
        // 1% : exactly 10 of every 1000 decisions sample.
        let one_pct = obs(10);
        let hits = (0..10_000).filter(|_| one_pct.should_sample()).count();
        assert_eq!(hits, 100, "deterministic 1% sampling");
        // Runtime rate change takes effect.
        let o = obs(0);
        o.set_sample_per_mille(1000);
        assert!(o.should_sample());
    }

    #[test]
    fn span_retires_after_all_six_stages() {
        let reg = Registry::new();
        let o = Obs::new(&reg, 1000, 8);
        assert!(o.should_sample());
        let span = o.begin_span(42);
        for (i, stage) in Stage::ALL.iter().enumerate() {
            assert_eq!(o.recent_spans(0).len(), 0, "not retired before stage {i}");
            o.record_stage(&span, *stage, 100 * (i as u64 + 1));
        }
        let spans = o.recent_spans(0);
        assert_eq!(spans.len(), 1);
        assert_eq!(spans[0].trace_id, 42);
        assert!(spans[0].stage_ns.iter().all(|&ns| ns > 0));
        assert_eq!(spans[0].stage_ns[Stage::AckWrite as usize], 600);
        // Each stage landed in its histogram.
        for stage in Stage::ALL {
            assert_eq!(o.stage_histogram(stage).count(), 1, "{}", stage.name());
        }
        // Double-fill keeps the first value and does not re-retire.
        o.record_stage(&span, Stage::Apply, 9_999_999);
        assert_eq!(o.recent_spans(0).len(), 1);
        assert_eq!(span.stage_nanos(Stage::Apply), 300);
    }

    #[test]
    fn span_log_bounded() {
        let reg = Registry::new();
        let o = Obs::new(&reg, 1000, 4);
        for t in 0..10u64 {
            let span = o.begin_span(t + 1);
            for stage in Stage::ALL {
                o.record_stage(&span, stage, 1);
            }
        }
        let spans = o.recent_spans(0);
        assert_eq!(spans.len(), 4, "log capped at capacity");
        assert_eq!(spans.last().unwrap().trace_id, 10, "newest kept");
        assert_eq!(o.recent_spans(2).len(), 2);
    }

    #[test]
    fn zero_elapsed_is_recorded_as_filled() {
        let o = obs(1000);
        let span = o.begin_span(7);
        o.record_stage(&span, Stage::FsyncSettle, 0);
        assert_eq!(span.stage_nanos(Stage::FsyncSettle), 1);
    }
}
