//! Prometheus text-format exposition (version 0.0.4) for the metrics
//! [`Registry`], served alongside the JSON `metrics` op.
//!
//! Rendering rules:
//!
//! * every instrument is prefixed `ata_` and name-sanitized to
//!   `[a-zA-Z0-9_]`;
//! * counters → `# TYPE ata_x counter`, gauges → `gauge` (non-finite
//!   gauge values render as `NaN`/`+Inf`/`-Inf`, which the text format
//!   permits);
//! * histograms → native `histogram` type with cumulative `le` buckets
//!   at each power-of-two boundary that holds samples (plus `+Inf`),
//!   `_sum` and `_count`;
//! * the per-stage latency family (`stage_latency_<stage>` in the
//!   registry) is folded into a single `ata_stage_latency_ns` metric
//!   with a `stage` label, so dashboards can aggregate or facet by
//!   stage without regex gymnastics.

use crate::metrics::Registry;
use crate::obs::Stage;

/// Render the whole registry in Prometheus text format.
pub fn render(registry: &Registry) -> String {
    let mut out = String::with_capacity(4096);

    for (name, value) in registry.counters_snapshot() {
        let name = sanitize(&name);
        out.push_str(&format!("# TYPE ata_{name} counter\n"));
        out.push_str(&format!("ata_{name} {value}\n"));
    }

    for (name, value) in registry.gauges_snapshot() {
        let name = sanitize(&name);
        out.push_str(&format!("# TYPE ata_{name} gauge\n"));
        out.push_str(&format!("ata_{name} {}\n", fmt_f64(value)));
    }

    let mut stage_hists = Vec::new();
    for (name, hist) in registry.histograms_snapshot() {
        if let Some(stage) = stage_of(&name) {
            stage_hists.push((stage, hist));
            continue;
        }
        let name = sanitize(&name);
        out.push_str(&format!("# TYPE ata_{name} histogram\n"));
        render_histogram(&mut out, &format!("ata_{name}"), "", &hist);
    }

    if !stage_hists.is_empty() {
        out.push_str("# TYPE ata_stage_latency_ns histogram\n");
        // Registry snapshots are name-sorted; re-sort into pipeline
        // (stage-declaration) order so the exposition reads causally.
        stage_hists.sort_by_key(|(s, _)| *s as u8);
        for (stage, hist) in &stage_hists {
            let label = format!("stage=\"{}\"", stage.name());
            render_histogram(&mut out, "ata_stage_latency_ns", &label, hist);
        }
    }

    out
}

/// Emit `_bucket`/`_sum`/`_count` lines for one histogram. `extra` is a
/// pre-rendered label (or empty) merged with the `le` label.
fn render_histogram(out: &mut String, name: &str, extra: &str, hist: &crate::metrics::Histogram) {
    let buckets = hist.bucket_counts();
    let mut cumulative = 0u64;
    for (i, &n) in buckets.iter().enumerate() {
        if n == 0 {
            continue; // sparse: only boundaries that hold samples
        }
        cumulative += n;
        let le = (1u128 << (i + 1)) - 1; // bucket i covers [2^i, 2^(i+1))
        let labels = join_labels(extra, &format!("le=\"{le}\""));
        out.push_str(&format!("{name}_bucket{{{labels}}} {cumulative}\n"));
    }
    let labels = join_labels(extra, "le=\"+Inf\"");
    out.push_str(&format!("{name}_bucket{{{labels}}} {cumulative}\n"));
    if extra.is_empty() {
        out.push_str(&format!("{name}_sum {}\n", hist.sum()));
        out.push_str(&format!("{name}_count {}\n", hist.count()));
    } else {
        out.push_str(&format!("{name}_sum{{{extra}}} {}\n", hist.sum()));
        out.push_str(&format!("{name}_count{{{extra}}} {}\n", hist.count()));
    }
}

fn join_labels(a: &str, b: &str) -> String {
    if a.is_empty() {
        b.to_string()
    } else {
        format!("{a},{b}")
    }
}

/// Map a registry histogram name back to its pipeline stage, if it is
/// one of the `stage_latency_*` family minted by [`crate::obs::Obs`].
fn stage_of(name: &str) -> Option<Stage> {
    let suffix = name.strip_prefix("stage_latency_")?;
    Stage::ALL.into_iter().find(|s| s.name() == suffix)
}

/// Prometheus metric names allow `[a-zA-Z0-9_:]`; we keep to the
/// conservative subset and fold anything else to `_`.
fn sanitize(name: &str) -> String {
    name.chars()
        .map(|c| if c.is_ascii_alphanumeric() || c == '_' { c } else { '_' })
        .collect()
}

/// Format an f64 the way Prometheus text format expects.
fn fmt_f64(v: f64) -> String {
    if v.is_nan() {
        "NaN".to_string()
    } else if v == f64::INFINITY {
        "+Inf".to_string()
    } else if v == f64::NEG_INFINITY {
        "-Inf".to_string()
    } else {
        format!("{v}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::stage_hist_name;

    /// Minimal exposition-format checker: every non-comment line is
    /// `name{labels} value` or `name value`, labels are `k="v"` pairs,
    /// value parses as f64 (or NaN/±Inf). Returns metric family names.
    fn parse_families(text: &str) -> Vec<String> {
        let mut families = Vec::new();
        for line in text.lines() {
            if let Some(rest) = line.strip_prefix("# TYPE ") {
                let mut it = rest.split_whitespace();
                let fam = it.next().expect("family name");
                let kind = it.next().expect("family kind");
                assert!(
                    matches!(kind, "counter" | "gauge" | "histogram"),
                    "bad kind: {line}"
                );
                families.push(fam.to_string());
                continue;
            }
            assert!(!line.starts_with('#'), "unexpected comment: {line}");
            let (name_part, value) = line.rsplit_once(' ').expect("name value");
            let bare = match name_part.find('{') {
                Some(open) => {
                    assert!(name_part.ends_with('}'), "unclosed labels: {line}");
                    let labels = &name_part[open + 1..name_part.len() - 1];
                    for pair in labels.split(',') {
                        let (k, v) = pair.split_once('=').expect("label pair");
                        assert!(!k.is_empty() && v.starts_with('"') && v.ends_with('"'));
                    }
                    &name_part[..open]
                }
                None => name_part,
            };
            assert!(
                bare.chars().all(|c| c.is_ascii_alphanumeric() || c == '_'),
                "bad metric name: {bare}"
            );
            assert!(
                matches!(value, "NaN" | "+Inf" | "-Inf") || value.parse::<f64>().is_ok(),
                "bad value: {line}"
            );
        }
        families
    }

    #[test]
    fn renders_counters_gauges_histograms() {
        let reg = Registry::new();
        reg.counter("pushes").add(42);
        reg.gauge("depth").set(3.5);
        reg.gauge("empty").set(f64::NAN);
        let h = reg.histogram("lat");
        h.record(3);
        h.record(100);
        let text = render(&reg);
        let families = parse_families(&text);
        assert!(families.contains(&"ata_pushes".to_string()));
        assert!(families.contains(&"ata_depth".to_string()));
        assert!(families.contains(&"ata_lat".to_string()));
        assert!(text.contains("ata_pushes 42\n"));
        assert!(text.contains("ata_depth 3.5\n"));
        assert!(text.contains("ata_empty NaN\n"));
        // value 3 → bucket [2,4) → le=3 cumulative 1; 100 → [64,128) → le=127.
        assert!(text.contains("ata_lat_bucket{le=\"3\"} 1\n"), "{text}");
        assert!(text.contains("ata_lat_bucket{le=\"127\"} 2\n"), "{text}");
        assert!(text.contains("ata_lat_bucket{le=\"+Inf\"} 2\n"));
        assert!(text.contains("ata_lat_sum 103\n"));
        assert!(text.contains("ata_lat_count 2\n"));
    }

    #[test]
    fn stage_family_folds_under_one_name_with_labels() {
        let reg = Registry::new();
        for s in Stage::ALL {
            reg.histogram(&stage_hist_name(s)).record(1 + s as u64);
        }
        let text = render(&reg);
        let families = parse_families(&text);
        assert_eq!(
            families
                .iter()
                .filter(|f| f.starts_with("ata_stage_latency"))
                .count(),
            1,
            "one folded family, not six: {families:?}"
        );
        for s in Stage::ALL {
            let want = format!("ata_stage_latency_ns_count{{stage=\"{}\"}} 1\n", s.name());
            assert!(text.contains(&want), "missing {want} in:\n{text}");
        }
        // Declaration order (admission first), not alphabetical.
        let adm = text.find("stage=\"admission\"").unwrap();
        let ack = text.find("stage=\"ack_write\"").unwrap();
        assert!(adm < ack, "stages out of pipeline order");
    }

    #[test]
    fn sanitizes_hostile_names() {
        let reg = Registry::new();
        reg.counter("weird-name.with:stuff").inc();
        let text = render(&reg);
        parse_families(&text);
        assert!(text.contains("ata_weird_name_with_stuff 1\n"));
    }

    #[test]
    fn empty_registry_renders_empty() {
        assert_eq!(render(&Registry::new()), "");
    }
}
