//! Lock-free flight recorder: a fixed-size ring of compact binary
//! events, one per shard.
//!
//! Writers claim a slot with one `fetch_add` on the head and publish
//! through a per-slot sequence (odd while writing, even when stable),
//! so concurrent writers never block and a snapshot can detect and skip
//! a slot that was mid-write — the classic seqlock, per slot. The ring
//! keeps the most recent `capacity` events; older ones are overwritten.
//!
//! Events are compact (five words) and carry the trace id and stream
//! handle, so a panic dump or an `introspect` snapshot can answer
//! "what were the last 4k things this shard did, and on whose behalf?"

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

/// What happened. Wire-stable discriminants (the event binary codec and
/// the v2 `introspect` op ship them as `u8`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EventKind {
    /// A push batch was applied; `arg` = sample count.
    Push = 1,
    /// A batch was dropped (backpressure); `arg` = sample count.
    Drop = 2,
    /// A batch was quarantined by a worker panic; `arg` = strike count.
    Quarantine = 3,
    /// A stream crossed the poison threshold and was isolated.
    Poison = 4,
    /// A request was refused with an overload rejection.
    Overload = 5,
    /// The shard's WAL rotated to a new segment; `arg` = new segment.
    WalRotation = 6,
    /// A checkpoint captured this shard; `arg` = streams captured.
    Checkpoint = 7,
    /// The replication shipper moved this shard's replica position;
    /// `arg` = bytes shipped in the batch.
    WalShip = 8,
    /// The node adopted a newer cluster ring (`cluster_hello` or a
    /// failover repoint); `arg` = the new ring version.
    RingUpdate = 9,
}

impl EventKind {
    /// Decode a wire discriminant.
    pub fn from_u8(v: u8) -> Option<EventKind> {
        match v {
            1 => Some(EventKind::Push),
            2 => Some(EventKind::Drop),
            3 => Some(EventKind::Quarantine),
            4 => Some(EventKind::Poison),
            5 => Some(EventKind::Overload),
            6 => Some(EventKind::WalRotation),
            7 => Some(EventKind::Checkpoint),
            8 => Some(EventKind::WalShip),
            9 => Some(EventKind::RingUpdate),
            _ => None,
        }
    }

    /// Human label (`ata top`, panic dumps).
    pub fn label(self) -> &'static str {
        match self {
            EventKind::Push => "push",
            EventKind::Drop => "drop",
            EventKind::Quarantine => "quarantine",
            EventKind::Poison => "poison",
            EventKind::Overload => "overload",
            EventKind::WalRotation => "wal_rotation",
            EventKind::Checkpoint => "checkpoint",
            EventKind::WalShip => "wal_ship",
            EventKind::RingUpdate => "ring_update",
        }
    }
}

/// One recorded event, as plain data.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Event {
    pub kind: EventKind,
    /// Which shard recorded it.
    pub shard: u16,
    /// Trace id of the request that caused it (0 = untraced).
    pub trace_id: u64,
    /// Stream handle involved (0 = none).
    pub handle: u64,
    /// Kind-specific argument (count, strike, segment …).
    pub arg: u64,
    /// Nanoseconds since the recorder was created.
    pub at_nanos: u64,
}

/// Byte length of one encoded event (see [`Event::encode`]).
pub const EVENT_ENCODED_LEN: usize = 1 + 2 + 8 + 8 + 8 + 8;

impl Event {
    /// Compact binary form: `[kind u8][shard u16][trace u64][handle u64]
    /// [arg u64][at_nanos u64]`, little-endian.
    pub fn encode(&self, enc: &mut crate::persist::codec::Enc) {
        enc.put_u8(self.kind as u8);
        enc.put_u16(self.shard);
        enc.put_u64(self.trace_id);
        enc.put_u64(self.handle);
        enc.put_u64(self.arg);
        enc.put_u64(self.at_nanos);
    }

    /// Decode one event; errors (never panics) on truncation or an
    /// unknown kind tag.
    pub fn decode(dec: &mut crate::persist::codec::Dec<'_>) -> Result<Event, String> {
        let tag = dec.get_u8()?;
        let kind =
            EventKind::from_u8(tag).ok_or_else(|| format!("unknown flight event kind {tag}"))?;
        Ok(Event {
            kind,
            shard: dec.get_u16()?,
            trace_id: dec.get_u64()?,
            handle: dec.get_u64()?,
            arg: dec.get_u64()?,
            at_nanos: dec.get_u64()?,
        })
    }
}

/// One ring slot: a seqlock word plus the event packed into four words.
/// `seq` is odd while a writer owns the slot; a reader accepts the slot
/// only when it observes the same even `seq` before and after copying.
#[derive(Default)]
struct Slot {
    seq: AtomicU64,
    meta: AtomicU64, // kind (low 8) | shard (next 16)
    trace_id: AtomicU64,
    handle: AtomicU64,
    arg: AtomicU64,
    at_nanos: AtomicU64,
}

/// The per-shard ring. All writes are wait-free (`fetch_add` + plain
/// stores); snapshots are lock-free and skip torn slots.
pub struct FlightRecorder {
    shard: u16,
    slots: Vec<Slot>,
    head: AtomicU64,
    epoch: Instant,
}

impl FlightRecorder {
    /// A ring holding the most recent `capacity` events (rounded up to
    /// a power of two, minimum 8).
    pub fn new(shard: u16, capacity: usize) -> FlightRecorder {
        let cap = capacity.max(8).next_power_of_two();
        FlightRecorder {
            shard,
            slots: (0..cap).map(|_| Slot::default()).collect(),
            head: AtomicU64::new(0),
            epoch: Instant::now(),
        }
    }

    /// Ring capacity (events retained).
    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Events recorded since creation (not capped by capacity).
    pub fn recorded(&self) -> u64 {
        self.head.load(Ordering::Relaxed)
    }

    /// Record one event. Wait-free; overwrites the oldest slot when the
    /// ring is full.
    ///
    /// Two writers can only collide on a slot when one has been lapped —
    /// stalled for a full ring revolution while another claimed the same
    /// slot `capacity` events later. A plain seqlock bump would go
    /// *even* during the second writer's store phase and let a reader
    /// accept the torn interleaving, so the claim is a CAS instead: the
    /// loser skips its write (the recorder is best-effort by design) and
    /// every publish value `2n+2` is unique to its event index, which
    /// makes the reader's before/after compare immune to ABA.
    pub fn record(&self, kind: EventKind, trace_id: u64, handle: u64, arg: u64) {
        let n = self.head.fetch_add(1, Ordering::Relaxed);
        let slot = &self.slots[(n as usize) & (self.slots.len() - 1)];
        let cur = slot.seq.load(Ordering::Relaxed);
        if cur & 1 == 1 {
            return; // lapped a stalled writer: drop this event
        }
        // Claim: advance to this event's odd phase.
        if slot
            .seq
            .compare_exchange(cur, 2 * n + 1, Ordering::AcqRel, Ordering::Relaxed)
            .is_err()
        {
            return; // raced another claimant: drop
        }
        slot.meta.store(
            (kind as u8 as u64) | ((self.shard as u64) << 8),
            Ordering::Relaxed,
        );
        slot.trace_id.store(trace_id, Ordering::Relaxed);
        slot.handle.store(handle, Ordering::Relaxed);
        slot.arg.store(arg, Ordering::Relaxed);
        slot.at_nanos
            .store(self.epoch.elapsed().as_nanos() as u64, Ordering::Relaxed);
        // Publish: this event's even phase (unique per index).
        slot.seq.store(2 * n + 2, Ordering::Release);
    }

    /// Snapshot the most recent events, oldest first, skipping any slot
    /// a writer was mid-flight in. `limit = 0` means the whole ring.
    pub fn snapshot(&self, limit: usize) -> Vec<Event> {
        let head = self.head.load(Ordering::Acquire);
        let cap = self.slots.len() as u64;
        let want = if limit == 0 { cap } else { (limit as u64).min(cap) };
        let live = head.min(want);
        let mut out = Vec::with_capacity(live as usize);
        for n in (head - live)..head {
            let slot = &self.slots[(n as usize) & (self.slots.len() - 1)];
            let before = slot.seq.load(Ordering::Acquire);
            if before & 1 == 1 {
                continue; // writer mid-flight
            }
            let meta = slot.meta.load(Ordering::Relaxed);
            let ev = Event {
                kind: match EventKind::from_u8((meta & 0xFF) as u8) {
                    Some(k) => k,
                    None => continue, // never-written slot
                },
                shard: ((meta >> 8) & 0xFFFF) as u16,
                trace_id: slot.trace_id.load(Ordering::Relaxed),
                handle: slot.handle.load(Ordering::Relaxed),
                arg: slot.arg.load(Ordering::Relaxed),
                at_nanos: slot.at_nanos.load(Ordering::Relaxed),
            };
            if slot.seq.load(Ordering::Acquire) != before {
                continue; // torn: overwritten while copying
            }
            out.push(ev);
        }
        out
    }

    /// Render the newest `limit` events as log lines (the supervisor's
    /// panic dump).
    pub fn dump(&self, limit: usize) -> String {
        let events = self.snapshot(limit);
        let mut out = String::with_capacity(events.len() * 64);
        for e in &events {
            out.push_str(&format!(
                "  [{:>12}ns shard {}] {} trace_id={} handle={} arg={}\n",
                e.at_nanos,
                e.shard,
                e.kind.label(),
                e.trace_id,
                e.handle,
                e.arg
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::persist::codec::{Dec, Enc};
    use std::sync::Arc;

    #[test]
    fn records_and_snapshots_in_order() {
        let r = FlightRecorder::new(3, 16);
        for i in 0..5u64 {
            r.record(EventKind::Push, 100 + i, 7, i);
        }
        let events = r.snapshot(0);
        assert_eq!(events.len(), 5);
        for (i, e) in events.iter().enumerate() {
            assert_eq!(e.kind, EventKind::Push);
            assert_eq!(e.shard, 3);
            assert_eq!(e.trace_id, 100 + i as u64);
            assert_eq!(e.arg, i as u64);
        }
        // at_nanos is nondecreasing.
        assert!(events.windows(2).all(|w| w[0].at_nanos <= w[1].at_nanos));
        assert_eq!(r.snapshot(2).len(), 2);
        assert_eq!(r.snapshot(2)[0].trace_id, 103);
    }

    #[test]
    fn ring_wraps_keeping_the_newest() {
        let r = FlightRecorder::new(0, 8); // capacity 8
        assert_eq!(r.capacity(), 8);
        for i in 0..100u64 {
            r.record(EventKind::Drop, i, 0, 0);
        }
        assert_eq!(r.recorded(), 100);
        let events = r.snapshot(0);
        assert_eq!(events.len(), 8, "ring holds exactly capacity");
        let ids: Vec<u64> = events.iter().map(|e| e.trace_id).collect();
        assert_eq!(ids, (92..100).collect::<Vec<u64>>(), "newest survive");
    }

    #[test]
    fn concurrent_writers_never_produce_torn_events() {
        // Property: every snapshotted event must be one a writer
        // actually wrote — trace_id encodes (writer, i) and arg must
        // equal trace_id ^ MARK, which a torn interleaving of two
        // writers' stores would violate.
        const MARK: u64 = 0xDEAD_BEEF_CAFE_F00D;
        let r = Arc::new(FlightRecorder::new(1, 64));
        let mut handles = Vec::new();
        for w in 0..4u64 {
            let r = Arc::clone(&r);
            handles.push(std::thread::spawn(move || {
                for i in 0..5_000u64 {
                    let id = (w << 32) | i;
                    r.record(EventKind::Push, id, id ^ MARK, id ^ MARK);
                }
            }));
        }
        let reader = {
            let r = Arc::clone(&r);
            std::thread::spawn(move || {
                let mut checked = 0u64;
                for _ in 0..200 {
                    for e in r.snapshot(0) {
                        assert_eq!(e.handle, e.trace_id ^ MARK, "torn event surfaced");
                        assert_eq!(e.arg, e.trace_id ^ MARK, "torn event surfaced");
                        checked += 1;
                    }
                }
                checked
            })
        };
        for h in handles {
            h.join().unwrap();
        }
        assert!(reader.join().unwrap() > 0, "reader saw events");
        assert_eq!(r.recorded(), 20_000);
        let final_events = r.snapshot(0);
        assert_eq!(final_events.len(), 64, "full ring after the storm");
    }

    #[test]
    fn event_codec_roundtrip_and_hostile_decode() {
        let ev = Event {
            kind: EventKind::Quarantine,
            shard: 9,
            trace_id: u64::MAX - 1,
            handle: 0x1234_5678_9ABC_DEF0,
            arg: 3,
            at_nanos: 1_000_000,
        };
        let mut enc = Enc::new();
        ev.encode(&mut enc);
        assert_eq!(enc.len(), EVENT_ENCODED_LEN);
        let bytes = enc.into_bytes();
        let got = Event::decode(&mut Dec::new(&bytes)).unwrap();
        assert_eq!(got, ev);
        // Unknown kind and truncations error, never panic.
        let mut bad = bytes.clone();
        bad[0] = 0xEE;
        assert!(Event::decode(&mut Dec::new(&bad)).is_err());
        for cut in 0..bytes.len() {
            assert!(Event::decode(&mut Dec::new(&bytes[..cut])).is_err());
        }
    }

    #[test]
    fn dump_renders_lines() {
        let r = FlightRecorder::new(2, 8);
        r.record(EventKind::Poison, 11, 22, 33);
        let dump = r.dump(8);
        assert!(dump.contains("poison"), "{dump}");
        assert!(dump.contains("trace_id=11"), "{dump}");
        assert!(dump.contains("shard 2"), "{dump}");
    }
}
