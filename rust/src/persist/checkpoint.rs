//! Snapshot files and the background checkpoint driver.
//!
//! A coordinator checkpoint is written as `snapshot-<seq>.ata` inside
//! the persist directory:
//!
//! ```text
//! [SNAPSHOT_MAGIC] [version: u16] [n_sections: u32]
//! n_sections × ( [len: u32] [crc32(bytes): u32] [bytes] )
//! ```
//!
//! Section bytes are opaque here — the coordinator packs one section per
//! shard (WAL position + that shard's bank arenas and slot streams; see
//! `coordinator::core`). Files are written atomically (`.tmp` +
//! `rename`) and validated on read (magic, version, per-section CRC), so
//! a crash mid-checkpoint leaves the previous snapshot authoritative and
//! a torn file is skipped, never loaded. The two most recent snapshots
//! are retained; older ones are pruned after a successful write.
//!
//! [`Checkpointer`] is the tiny interval driver `ata serve` uses for
//! background checkpointing: a named thread that invokes the supplied
//! checkpoint closure every `interval`, stopping promptly on drop.

use super::codec::{crc32, FORMAT_VERSION, MIN_FORMAT_VERSION, SNAPSHOT_MAGIC};
use std::fs;
use std::io::{Read, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

fn snapshot_path(dir: &Path, seq: u64) -> PathBuf {
    dir.join(format!("snapshot-{seq:08}.ata"))
}

/// Snapshot sequence numbers present in `dir`, ascending.
pub fn list_snapshots(dir: &Path) -> Vec<u64> {
    let mut seqs = Vec::new();
    let Ok(entries) = fs::read_dir(dir) else {
        return seqs;
    };
    for entry in entries.flatten() {
        let name = entry.file_name();
        let Some(name) = name.to_str() else { continue };
        if let Some(num) = name
            .strip_prefix("snapshot-")
            .and_then(|s| s.strip_suffix(".ata"))
        {
            if let Ok(seq) = num.parse::<u64>() {
                seqs.push(seq);
            }
        }
    }
    seqs.sort_unstable();
    seqs
}

/// Atomically write the next snapshot (tmp + fsync + rename), prune all
/// but the two newest, and return `(path, seq, bytes_written)`.
pub fn write_snapshot(dir: &Path, sections: &[Vec<u8>]) -> Result<(PathBuf, u64, u64), String> {
    fs::create_dir_all(dir).map_err(|e| format!("create persist dir {}: {e}", dir.display()))?;
    let seq = list_snapshots(dir).last().map_or(0, |s| s + 1);
    let path = snapshot_path(dir, seq);
    let tmp = path.with_extension("ata.tmp");
    let mut buf = Vec::new();
    buf.extend_from_slice(SNAPSHOT_MAGIC);
    buf.extend_from_slice(&FORMAT_VERSION.to_le_bytes());
    buf.extend_from_slice(&(sections.len() as u32).to_le_bytes());
    for s in sections {
        buf.extend_from_slice(&(s.len() as u32).to_le_bytes());
        buf.extend_from_slice(&crc32(s).to_le_bytes());
        buf.extend_from_slice(s);
    }
    let bytes = buf.len() as u64;
    {
        let mut f =
            fs::File::create(&tmp).map_err(|e| format!("create {}: {e}", tmp.display()))?;
        f.write_all(&buf)
            .map_err(|e| format!("write {}: {e}", tmp.display()))?;
        f.sync_all()
            .map_err(|e| format!("fsync {}: {e}", tmp.display()))?;
    }
    fs::rename(&tmp, &path).map_err(|e| format!("rename into {}: {e}", path.display()))?;
    // Prune: keep this snapshot and its predecessor as a fallback.
    for old in list_snapshots(dir) {
        if old + 1 < seq {
            let _ = fs::remove_file(snapshot_path(dir, old));
        }
    }
    Ok((path, seq, bytes))
}

/// Parse one snapshot file into its sections; `Err` on any corruption
/// (bad magic/version, torn section, CRC mismatch) — never panics.
pub fn read_snapshot(path: &Path) -> Result<Vec<Vec<u8>>, String> {
    let mut bytes = Vec::new();
    fs::File::open(path)
        .and_then(|mut f| f.read_to_end(&mut bytes))
        .map_err(|e| format!("read snapshot {}: {e}", path.display()))?;
    if bytes.len() < 10 || &bytes[..4] != SNAPSHOT_MAGIC {
        return Err("bad snapshot magic".into());
    }
    let version = u16::from_le_bytes([bytes[4], bytes[5]]);
    if !(MIN_FORMAT_VERSION..=FORMAT_VERSION).contains(&version) {
        return Err(format!(
            "snapshot format version {version} unsupported (this build speaks \
             {MIN_FORMAT_VERSION}..={FORMAT_VERSION})"
        ));
    }
    let n = u32::from_le_bytes([bytes[6], bytes[7], bytes[8], bytes[9]]) as usize;
    let mut sections = Vec::new();
    let mut pos = 10usize;
    for i in 0..n {
        if bytes.len() - pos < 8 {
            return Err(format!("snapshot section {i} header truncated"));
        }
        let len = u32::from_le_bytes([bytes[pos], bytes[pos + 1], bytes[pos + 2], bytes[pos + 3]])
            as usize;
        let want = u32::from_le_bytes([
            bytes[pos + 4],
            bytes[pos + 5],
            bytes[pos + 6],
            bytes[pos + 7],
        ]);
        pos += 8;
        if bytes.len() - pos < len {
            return Err(format!("snapshot section {i} truncated"));
        }
        let body = &bytes[pos..pos + len];
        if crc32(body) != want {
            return Err(format!("snapshot section {i} CRC mismatch"));
        }
        sections.push(body.to_vec());
        pos += len;
    }
    Ok(sections)
}

/// Newest snapshot in `dir` that parses and CRC-validates, if any —
/// torn or bit-flipped files fall back to the predecessor.
pub fn latest_valid_snapshot(dir: &Path) -> Option<(u64, PathBuf, Vec<Vec<u8>>)> {
    for seq in list_snapshots(dir).into_iter().rev() {
        let path = snapshot_path(dir, seq);
        if let Ok(sections) = read_snapshot(&path) {
            return Some((seq, path, sections));
        }
    }
    None
}

/// Background checkpoint driver: runs `tick` every `interval` on a
/// named thread until dropped (or [`Checkpointer::stop`]).
pub struct Checkpointer {
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl Checkpointer {
    /// `tick` returns `Err(reason)` to log a warning and keep going.
    pub fn start(
        interval: Duration,
        tick: impl Fn() -> Result<(), String> + Send + 'static,
    ) -> Checkpointer {
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let handle = std::thread::Builder::new()
            .name("ata-checkpoint".to_string())
            .spawn(move || {
                let step = Duration::from_millis(25).min(interval.max(Duration::from_millis(1)));
                let mut elapsed = Duration::ZERO;
                loop {
                    if stop2.load(Ordering::SeqCst) {
                        break;
                    }
                    std::thread::sleep(step);
                    elapsed += step;
                    if elapsed >= interval {
                        elapsed = Duration::ZERO;
                        if let Err(e) = tick() {
                            crate::log_warn!("persist", "background checkpoint failed: {e}");
                        }
                    }
                }
            })
            .expect("spawn checkpointer");
        Checkpointer {
            stop,
            handle: Some(handle),
        }
    }

    /// Stop and join the driver thread.
    pub fn stop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Checkpointer {
    fn drop(&mut self) {
        self.stop();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::temp_dir;

    #[test]
    fn snapshot_write_read_roundtrip_and_pruning() {
        let dir = temp_dir("ckpt-roundtrip");
        let sections = vec![vec![1u8, 2, 3], vec![], vec![0xFF; 100]];
        let (path, seq, bytes) = write_snapshot(&dir, &sections).unwrap();
        assert_eq!(seq, 0);
        assert!(bytes > 0);
        assert_eq!(read_snapshot(&path).unwrap(), sections);
        // Subsequent snapshots increment and prune to the newest two.
        for _ in 0..4 {
            write_snapshot(&dir, &sections).unwrap();
        }
        let seqs = list_snapshots(&dir);
        assert_eq!(seqs, vec![3, 4]);
        let (latest, _, got) = latest_valid_snapshot(&dir).unwrap();
        assert_eq!(latest, 4);
        assert_eq!(got, sections);
    }

    #[test]
    fn corrupt_latest_falls_back_to_predecessor() {
        let dir = temp_dir("ckpt-fallback");
        write_snapshot(&dir, &[vec![1, 1, 1]]).unwrap();
        let (path, seq, _) = write_snapshot(&dir, &[vec![2, 2, 2]]).unwrap();
        assert_eq!(seq, 1);
        // Flip a byte inside the newest file's section body.
        let mut bytes = fs::read(&path).unwrap();
        let last = bytes.len() - 1;
        bytes[last] ^= 0x01;
        fs::write(&path, &bytes).unwrap();
        let (seq, _, sections) = latest_valid_snapshot(&dir).unwrap();
        assert_eq!(seq, 0);
        assert_eq!(sections, vec![vec![1, 1, 1]]);
        // Truncations of every snapshot never panic.
        for cut in 0..bytes.len() {
            fs::write(&path, &bytes[..cut]).unwrap();
            let _ = read_snapshot(&path);
        }
    }

    #[test]
    fn checkpointer_ticks_and_stops() {
        use std::sync::atomic::AtomicUsize;
        let n = Arc::new(AtomicUsize::new(0));
        let n2 = Arc::clone(&n);
        let mut c = Checkpointer::start(Duration::from_millis(30), move || {
            n2.fetch_add(1, Ordering::SeqCst);
            Ok(())
        });
        std::thread::sleep(Duration::from_millis(200));
        c.stop();
        let ticks = n.load(Ordering::SeqCst);
        assert!(ticks >= 2, "ticks={ticks}");
        // Stopped: no further ticks.
        std::thread::sleep(Duration::from_millis(80));
        assert_eq!(n.load(Ordering::SeqCst), ticks);
    }
}
