//! Versioned, length-prefixed binary codec for durable estimator state.
//!
//! Everything the persist layer writes — per-estimator state payloads,
//! WAL records, snapshot sections — is built from the same two
//! primitives: [`Enc`] (append-only little-endian writer) and [`Dec`]
//! (bounds-checked reader that returns `Err` on any malformed input and
//! **never** panics, which the codec fuzz target enforces).
//!
//! ## Canonical per-estimator state payloads
//!
//! Each estimator's state serializes to one self-describing payload:
//!
//! ```text
//! [kind: u8] [dim: u32] [params…] [counters…] [f64 state slices…]
//! ```
//!
//! The kind tags are [`tag`] constants; the per-estimator field layouts
//! are documented in the README's "Durable state" section and written by
//! `Averager::export_state` / `BankState::export_rows`. Accumulator
//! slices are always written in *logical* order (oldest → newest), never
//! physical arena order, so a payload exported from a planar bank row
//! imports bit-identically into a slot estimator and vice versa.
//!
//! Standalone payloads (the wire `export_state`/`restore`/`merge_state`
//! ops) wrap the payload in a tiny envelope: [`STATE_MAGIC`], format
//! version, payload length, CRC32 — see [`frame_state`]/[`unframe_state`].

/// Magic prefix of a framed standalone state payload.
pub const STATE_MAGIC: &[u8; 4] = b"ATAE";
/// Magic prefix of a coordinator snapshot file.
pub const SNAPSHOT_MAGIC: &[u8; 4] = b"ATAS";
/// Magic prefix of a WAL segment file.
pub const WAL_MAGIC: &[u8; 4] = b"ATAW";
/// Current on-disk format version (shared by snapshots, WAL and framed
/// state payloads; bump on any layout change).
///
/// v2: every estimator payload carries its moment side state (`x²`
/// accumulator twins; TrueWindow additionally ships its live `Σx`/`Σx²`
/// and resum countdown). v1 payloads decode differently, so they are
/// rejected with a version error instead of misparsing — a v1 persist
/// directory needs the previous release to drain (checkpoint, export)
/// before upgrading.
///
/// v3: adds the `TWO_TAIL` estimator tag. Every v2 payload layout is
/// unchanged, so v2 frames still decode ([`MIN_FORMAT_VERSION`]); only
/// the envelope version written for NEW frames moved.
pub const FORMAT_VERSION: u16 = 3;

/// Oldest envelope version this build still decodes. v2 payloads are a
/// strict subset of v3 (same layouts, fewer tags), so a v2 persist
/// directory or exported state restores directly.
pub const MIN_FORMAT_VERSION: u16 = 2;

/// Estimator kind tags of the canonical state payloads.
pub mod tag {
    pub const EXP: u8 = 1;
    pub const GEA: u8 = 2;
    pub const AWA2: u8 = 3;
    pub const AWA_MULTI: u8 = 4;
    pub const TRUE_WINDOW: u8 = 5;
    pub const RAW_TAIL: u8 = 6;
    pub const RESTART: u8 = 7;
    pub const EH: u8 = 8;
    pub const TWO_TAIL: u8 = 9;
}

/// Append-only little-endian byte writer.
#[derive(Default)]
pub struct Enc {
    buf: Vec<u8>,
}

impl Enc {
    pub fn new() -> Enc {
        Enc { buf: Vec::new() }
    }

    /// Wrap an existing buffer (cleared first), reusing its allocation —
    /// the wire codec encodes into pooled buffers through this.
    pub fn with_buf(mut buf: Vec<u8>) -> Enc {
        buf.clear();
        Enc { buf }
    }

    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }

    pub fn as_bytes(&self) -> &[u8] {
        &self.buf
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Reset to empty, keeping the allocation (hot-path reuse).
    pub fn clear(&mut self) {
        self.buf.clear();
    }

    pub fn put_u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn put_u16(&mut self, v: u16) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Length-prefixed (u32) raw bytes.
    pub fn put_bytes(&mut self, v: &[u8]) {
        self.put_u32(v.len() as u32);
        self.buf.extend_from_slice(v);
    }

    /// Length-prefixed (u32) UTF-8 string.
    pub fn put_str(&mut self, v: &str) {
        self.put_bytes(v.as_bytes());
    }

    /// Length-prefixed (u32 element count) f64 slice.
    pub fn put_f64_slice(&mut self, v: &[f64]) {
        self.put_u32(v.len() as u32);
        for &x in v {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }

    /// Raw f64 run with NO length prefix (callers that already framed
    /// the element count, e.g. the bank arena gather).
    pub fn put_f64_raw(&mut self, v: &[f64]) {
        for &x in v {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }
}

/// Bounds-checked little-endian byte reader over a borrowed slice.
///
/// Every getter returns `Err` (never panics) on exhausted or malformed
/// input; `Dec` is the only parser the persist layer uses, so "corrupt
/// bytes are an error, not a crash" holds everywhere by construction.
pub struct Dec<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Dec<'a> {
    pub fn new(buf: &'a [u8]) -> Dec<'a> {
        Dec { buf, pos: 0 }
    }

    /// Bytes not yet consumed.
    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    /// Current cursor position.
    pub fn position(&self) -> usize {
        self.pos
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], String> {
        if self.remaining() < n {
            return Err(format!(
                "truncated input: need {n} bytes at offset {}, have {}",
                self.pos,
                self.remaining()
            ));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn get_u8(&mut self) -> Result<u8, String> {
        Ok(self.take(1)?[0])
    }

    pub fn get_u16(&mut self) -> Result<u16, String> {
        let b = self.take(2)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }

    pub fn get_u32(&mut self) -> Result<u32, String> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    pub fn get_u64(&mut self) -> Result<u64, String> {
        let b = self.take(8)?;
        let mut a = [0u8; 8];
        a.copy_from_slice(b);
        Ok(u64::from_le_bytes(a))
    }

    pub fn get_f64(&mut self) -> Result<f64, String> {
        let b = self.take(8)?;
        let mut a = [0u8; 8];
        a.copy_from_slice(b);
        Ok(f64::from_le_bytes(a))
    }

    /// Length-prefixed raw bytes (borrowed).
    pub fn get_bytes(&mut self) -> Result<&'a [u8], String> {
        let n = self.get_u32()? as usize;
        // A hostile length must not trigger a huge allocation or wrap;
        // take() bounds-checks against the actual remaining bytes.
        self.take(n)
    }

    /// Length-prefixed UTF-8 string.
    pub fn get_str(&mut self) -> Result<String, String> {
        let b = self.get_bytes()?;
        String::from_utf8(b.to_vec()).map_err(|_| "invalid UTF-8 string".to_string())
    }

    /// Length-prefixed f64 slice (owned).
    pub fn get_f64_vec(&mut self) -> Result<Vec<f64>, String> {
        let n = self.get_u32()? as usize;
        self.get_f64_raw(n)
    }

    /// Exactly `n` raw f64s (no length prefix).
    pub fn get_f64_raw(&mut self, n: usize) -> Result<Vec<f64>, String> {
        let bytes = n
            .checked_mul(8)
            .ok_or_else(|| "f64 run length overflows".to_string())?;
        let b = self.take(bytes)?;
        let mut out = Vec::with_capacity(n);
        for c in b.chunks_exact(8) {
            let mut a = [0u8; 8];
            a.copy_from_slice(c);
            out.push(f64::from_le_bytes(a));
        }
        Ok(out)
    }

    /// Exactly `n` raw f64s written straight into `out` (no allocation).
    pub fn get_f64_into(&mut self, out: &mut [f64]) -> Result<(), String> {
        let bytes = out
            .len()
            .checked_mul(8)
            .ok_or_else(|| "f64 run length overflows".to_string())?;
        let b = self.take(bytes)?;
        for (o, c) in out.iter_mut().zip(b.chunks_exact(8)) {
            let mut a = [0u8; 8];
            a.copy_from_slice(c);
            *o = f64::from_le_bytes(a);
        }
        Ok(())
    }
}

/// CRC-32 (IEEE 802.3, reflected) over `bytes` — the integrity check on
/// WAL records, snapshot sections and framed state payloads.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut crc: u32 = !0;
    for &b in bytes {
        crc ^= b as u32;
        for _ in 0..8 {
            let mask = (crc & 1).wrapping_neg();
            crc = (crc >> 1) ^ (0xEDB8_8320 & mask);
        }
    }
    !crc
}

/// Wrap a canonical state payload in the standalone envelope:
/// magic + version + u32 length + payload + u32 CRC of the payload.
pub fn frame_state(payload: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(payload.len() + 14);
    out.extend_from_slice(STATE_MAGIC);
    out.extend_from_slice(&FORMAT_VERSION.to_le_bytes());
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(payload);
    out.extend_from_slice(&crc32(payload).to_le_bytes());
    out
}

/// Validate the standalone envelope and return the inner payload.
pub fn unframe_state(bytes: &[u8]) -> Result<&[u8], String> {
    let mut d = Dec::new(bytes);
    let magic = d.take(4)?;
    if magic != STATE_MAGIC {
        return Err("bad state magic (not an exported estimator state)".into());
    }
    let version = d.get_u16()?;
    if !(MIN_FORMAT_VERSION..=FORMAT_VERSION).contains(&version) {
        return Err(format!(
            "state format version {version} unsupported (this build speaks \
             {MIN_FORMAT_VERSION}..={FORMAT_VERSION})"
        ));
    }
    let len = d.get_u32()? as usize;
    let payload = d.take(len)?;
    let want = d.get_u32()?;
    let got = crc32(payload);
    if got != want {
        return Err(format!("state CRC mismatch: {got:#010x} != {want:#010x}"));
    }
    Ok(payload)
}

/// Lowercase hex encoding (the JSON wire form of binary state).
pub fn to_hex(bytes: &[u8]) -> String {
    const DIGITS: &[u8; 16] = b"0123456789abcdef";
    let mut s = String::with_capacity(bytes.len() * 2);
    for &b in bytes {
        s.push(DIGITS[(b >> 4) as usize] as char);
        s.push(DIGITS[(b & 0xf) as usize] as char);
    }
    s
}

/// Hex decoding; rejects odd lengths and non-hex characters.
pub fn from_hex(s: &str) -> Result<Vec<u8>, String> {
    let bytes = s.as_bytes();
    if bytes.len() % 2 != 0 {
        return Err("hex string has odd length".into());
    }
    let nib = |c: u8| -> Result<u8, String> {
        match c {
            b'0'..=b'9' => Ok(c - b'0'),
            b'a'..=b'f' => Ok(c - b'a' + 10),
            b'A'..=b'F' => Ok(c - b'A' + 10),
            _ => Err(format!("invalid hex character {:?}", c as char)),
        }
    };
    let mut out = Vec::with_capacity(bytes.len() / 2);
    for pair in bytes.chunks_exact(2) {
        out.push((nib(pair[0])? << 4) | nib(pair[1])?);
    }
    Ok(out)
}

/// Encode a [`crate::averagers::WindowKind`] (shared by several payloads).
pub fn put_window(enc: &mut Enc, w: &crate::averagers::WindowKind) {
    match *w {
        crate::averagers::WindowKind::Fixed { k } => {
            enc.put_u8(0);
            enc.put_u64(k);
        }
        crate::averagers::WindowKind::Growing { c } => {
            enc.put_u8(1);
            enc.put_f64(c);
        }
    }
}

/// Decode a [`crate::averagers::WindowKind`].
pub fn get_window(dec: &mut Dec<'_>) -> Result<crate::averagers::WindowKind, String> {
    match dec.get_u8()? {
        0 => Ok(crate::averagers::WindowKind::Fixed { k: dec.get_u64()? }),
        1 => Ok(crate::averagers::WindowKind::Growing { c: dec.get_f64()? }),
        other => Err(format!("unknown window kind tag {other}")),
    }
}

/// Window echo check: consume the payload's [`crate::averagers::
/// WindowKind`] and require it to match the estimator's (follows
/// [`check_header`] in every windowed payload).
pub fn check_window(
    dec: &mut Dec<'_>,
    want: &crate::averagers::WindowKind,
) -> Result<(), String> {
    let kind = get_window(dec)?;
    if kind != *want {
        return Err(format!(
            "state payload window {kind:?} does not match estimator {want:?}"
        ));
    }
    Ok(())
}

/// Shared payload-header check: kind tag and dimensionality must match
/// the estimator the payload is being imported into.
pub fn check_header(dec: &mut Dec<'_>, want_tag: u8, want_dim: usize) -> Result<(), String> {
    let tag = dec.get_u8()?;
    if tag != want_tag {
        return Err(format!(
            "state payload kind {tag} does not match estimator kind {want_tag}"
        ));
    }
    let dim = dec.get_u32()? as usize;
    if dim != want_dim {
        return Err(format!(
            "state payload dim {dim} does not match estimator dim {want_dim}"
        ));
    }
    Ok(())
}

/// Length-prefixed state vector whose length must equal `want_len`
/// (an estimator's dim or accumulator size).
pub fn get_state_vec(dec: &mut Dec<'_>, want_len: usize) -> Result<Vec<f64>, String> {
    let v = dec.get_f64_vec()?;
    if v.len() != want_len {
        return Err(format!(
            "state vector length {} != expected {want_len}",
            v.len()
        ));
    }
    Ok(v)
}

/// Parameter echo check: an imported payload's spec parameter must be
/// bit-identical to the live estimator's.
pub fn check_param(name: &str, got: f64, want: f64) -> Result<(), String> {
    if got.to_bits() != want.to_bits() {
        return Err(format!(
            "state payload {name}={got} does not match estimator {name}={want}"
        ));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn enc_dec_roundtrip_all_primitives() {
        let mut e = Enc::new();
        e.put_u8(7);
        e.put_u16(300);
        e.put_u32(1 << 20);
        e.put_u64(u64::MAX - 3);
        e.put_f64(-2.5);
        e.put_str("stream/0");
        e.put_bytes(&[1, 2, 3]);
        e.put_f64_slice(&[1.0, -1.0]);
        e.put_f64_raw(&[9.0]);
        let bytes = e.into_bytes();
        let mut d = Dec::new(&bytes);
        assert_eq!(d.get_u8().unwrap(), 7);
        assert_eq!(d.get_u16().unwrap(), 300);
        assert_eq!(d.get_u32().unwrap(), 1 << 20);
        assert_eq!(d.get_u64().unwrap(), u64::MAX - 3);
        assert_eq!(d.get_f64().unwrap(), -2.5);
        assert_eq!(d.get_str().unwrap(), "stream/0");
        assert_eq!(d.get_bytes().unwrap(), &[1, 2, 3]);
        assert_eq!(d.get_f64_vec().unwrap(), vec![1.0, -1.0]);
        assert_eq!(d.get_f64_raw(1).unwrap(), vec![9.0]);
        assert_eq!(d.remaining(), 0);
        assert!(d.get_u8().is_err());
    }

    #[test]
    fn dec_rejects_truncation_and_hostile_lengths() {
        let mut e = Enc::new();
        e.put_str("hello");
        let mut bytes = e.into_bytes();
        bytes.truncate(6); // cut inside the string body
        assert!(Dec::new(&bytes).get_str().is_err());
        // A length prefix far beyond the buffer must error, not allocate.
        let huge = (u32::MAX).to_le_bytes();
        assert!(Dec::new(&huge).get_bytes().is_err());
        assert!(Dec::new(&huge).get_f64_vec().is_err());
    }

    #[test]
    fn crc32_known_vectors() {
        // IEEE CRC-32 of "123456789" is the classic check value.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn state_frame_roundtrip_and_corruption() {
        let payload = b"estimator state bytes".to_vec();
        let framed = frame_state(&payload);
        assert_eq!(unframe_state(&framed).unwrap(), &payload[..]);
        // Any single bit flip must be caught (magic, version, len, body
        // or CRC).
        for i in 0..framed.len() {
            let mut bad = framed.clone();
            bad[i] ^= 0x40;
            assert!(unframe_state(&bad).is_err(), "bit flip at byte {i}");
        }
        // Truncations at every offset must error, never panic.
        for cut in 0..framed.len() {
            assert!(unframe_state(&framed[..cut]).is_err(), "cut at {cut}");
        }
    }

    #[test]
    fn hex_roundtrip_and_rejects() {
        let bytes = vec![0u8, 1, 0xAB, 0xFF, 0x10];
        let s = to_hex(&bytes);
        assert_eq!(s, "0001abff10");
        assert_eq!(from_hex(&s).unwrap(), bytes);
        assert_eq!(from_hex("ABCDEF").unwrap(), vec![0xAB, 0xCD, 0xEF]);
        assert!(from_hex("abc").is_err());
        assert!(from_hex("zz").is_err());
    }

    #[test]
    fn window_kind_roundtrip() {
        use crate::averagers::WindowKind;
        for w in [WindowKind::Fixed { k: 17 }, WindowKind::Growing { c: 0.25 }] {
            let mut e = Enc::new();
            put_window(&mut e, &w);
            let bytes = e.into_bytes();
            let got = get_window(&mut Dec::new(&bytes)).unwrap();
            assert_eq!(got, w);
        }
        assert!(get_window(&mut Dec::new(&[9])).is_err());
    }
}
