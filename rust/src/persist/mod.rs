//! Durable state: versioned snapshot codec, write-ahead log, and
//! checkpoint files.
//!
//! The paper's whole point is that anytime tail averages live in O(1)
//! memory — which also means a crash destroys state that took millions
//! of observations to build and cannot be recomputed without replaying
//! the stream. This subsystem makes every estimator's state a
//! *serializable, mergeable partial aggregate* (the timescaledb-toolkit
//! design) and gives the coordinator crash durability:
//!
//! * [`codec`] — the little-endian binary primitives ([`codec::Enc`],
//!   [`codec::Dec`]), CRC32, hex, and the canonical per-estimator state
//!   payload conventions used by `Averager::{export_state, import_state,
//!   merge_state}` and the planar banks' bulk `export_rows`.
//! * [`wal`] — per-shard write-ahead log segments with CRC-framed
//!   records, rotation, position tracking, truncation and corruption-
//!   tolerant replay.
//! * [`checkpoint`] — atomic snapshot files (tmp + rename, per-section
//!   CRC, keep-two retention) and the background [`checkpoint::
//!   Checkpointer`] driver.
//!
//! The coordinator-side glue — quiescing shards at drain-cycle
//! boundaries, `Coordinator::{checkpoint, recover}`, and the
//! `checkpoint`/`restore`/`merge_state` wire ops — lives in
//! [`crate::coordinator`]; this module is deliberately coordinator-
//! agnostic so the codec and WAL can be reused (and fuzzed) standalone.

pub mod checkpoint;
pub mod codec;
pub mod wal;
