//! Write-ahead log: per-shard segment files of CRC-framed push batches.
//!
//! Each coordinator shard owns one WAL directory
//! (`<persist.dir>/wal/shard-<i>/`) holding numbered segment files
//! (`seg-<n>.wal`). A segment starts with a 6-byte header
//! ([`codec::WAL_MAGIC`] + format version) followed by framed records:
//!
//! ```text
//! [payload_len: u32] [crc32(payload): u32] [payload]
//! payload = [kind: u8] …
//!   kind 1 (push):       stream str, count u32, data f64[count·dim]
//!   kind 2 (register):   stream str, dim u32, spec-label str
//!   kind 3 (unregister): stream str
//! ```
//!
//! The shard worker appends every accepted message *before* applying it,
//! so on crash the WAL tail is a superset of the applied-but-not-yet-
//! checkpointed work. Registration/unregistration flows through the same
//! per-shard queue as pushes, so WAL order equals apply order.
//!
//! Segments rotate once they exceed `segment_bytes`; a checkpoint
//! records each shard's `(segment, offset)` position and deletes fully
//! obsolete segments ([`truncate_before`]). Replay ([`replay`]) walks
//! the segments from a recorded position and stops — cleanly, never
//! panicking — at the first torn, truncated, or bit-flipped record,
//! which is exactly the crash-recovery contract: every fully-framed
//! record before the corruption point is recovered, nothing after.
//!
//! ## Group commit
//!
//! With `fsync = true` every append pays a disk sync — the durability
//! ceiling of the whole service. [`WalWriter::set_group_commit`] opens a
//! bounded window (`persist.group_commit_micros`): appends inside it are
//! written immediately but share ONE deferred `sync_data`, issued when
//! the window elapses, on segment rotation, or when a caller forces a
//! [`WalWriter::commit`] (the coordinator forces one before acking
//! `sync` and before checkpoints, so the durable-ack contract is
//! unchanged). Grouping only re-times fsyncs — the bytes written are
//! identical to per-append mode, so replay and recovery are oblivious
//! to it.

use super::codec::{crc32, Dec, Enc, FORMAT_VERSION, MIN_FORMAT_VERSION, WAL_MAGIC};
use crate::metrics::Counter;
use crate::testkit::chaos;
use std::fs::{self, File, OpenOptions};
use std::io::{Read, Write};
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Byte length of the segment header (magic + version).
const HEADER_LEN: u64 = 6;

/// A durable position in one shard's WAL: the next byte to be written.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct WalPosition {
    pub segment: u64,
    pub offset: u64,
}

/// One logical WAL record.
#[derive(Clone, Debug, PartialEq)]
pub enum WalRecord {
    /// `count` consecutive samples packed flat in `data`.
    Push {
        stream: String,
        count: usize,
        data: Vec<f64>,
    },
    Register {
        stream: String,
        dim: usize,
        spec: String,
    },
    Unregister {
        stream: String,
    },
}

impl WalRecord {
    fn encode(&self, enc: &mut Enc) {
        match self {
            WalRecord::Push {
                stream,
                count,
                data,
            } => {
                enc.put_u8(1);
                enc.put_str(stream);
                enc.put_u32(*count as u32);
                enc.put_f64_slice(data);
            }
            WalRecord::Register { stream, dim, spec } => {
                enc.put_u8(2);
                enc.put_str(stream);
                enc.put_u32(*dim as u32);
                enc.put_str(spec);
            }
            WalRecord::Unregister { stream } => {
                enc.put_u8(3);
                enc.put_str(stream);
            }
        }
    }

    fn decode(dec: &mut Dec<'_>) -> Result<WalRecord, String> {
        match dec.get_u8()? {
            1 => {
                let stream = dec.get_str()?;
                let count = dec.get_u32()? as usize;
                let data = dec.get_f64_vec()?;
                Ok(WalRecord::Push {
                    stream,
                    count,
                    data,
                })
            }
            2 => Ok(WalRecord::Register {
                stream: dec.get_str()?,
                dim: dec.get_u32()? as usize,
                spec: dec.get_str()?,
            }),
            3 => Ok(WalRecord::Unregister {
                stream: dec.get_str()?,
            }),
            other => Err(format!("unknown WAL record kind {other}")),
        }
    }
}

fn segment_path(dir: &Path, seq: u64) -> PathBuf {
    dir.join(format!("seg-{seq:08}.wal"))
}

/// The on-disk path of segment `seq` under `dir`. Public so the
/// replication standby can append shipped bytes to the exact layout
/// `recover` expects, without duplicating the naming scheme.
pub fn segment_file(dir: &Path, seq: u64) -> PathBuf {
    segment_path(dir, seq)
}

/// Segment sequence numbers present in `dir`, ascending.
pub fn list_segments(dir: &Path) -> Vec<u64> {
    let mut seqs = Vec::new();
    let Ok(entries) = fs::read_dir(dir) else {
        return seqs;
    };
    for entry in entries.flatten() {
        let name = entry.file_name();
        let Some(name) = name.to_str() else { continue };
        if let Some(num) = name
            .strip_prefix("seg-")
            .and_then(|s| s.strip_suffix(".wal"))
        {
            if let Ok(seq) = num.parse::<u64>() {
                seqs.push(seq);
            }
        }
    }
    seqs.sort_unstable();
    seqs
}

/// Delete every segment with sequence number strictly below `keep_from`
/// (the checkpoint's recorded segment stays — its tail may hold
/// post-checkpoint records). Returns the number of segments removed.
pub fn truncate_before(dir: &Path, keep_from: u64) -> usize {
    let mut removed = 0;
    for seq in list_segments(dir) {
        if seq < keep_from && fs::remove_file(segment_path(dir, seq)).is_ok() {
            removed += 1;
        }
    }
    removed
}

/// Byte length of segment `seq` in `dir` (header included), or an
/// error when the segment does not exist. The replication shipper uses
/// this to probe how much of a sealed segment remains to ship.
pub fn segment_len(dir: &Path, seq: u64) -> Result<u64, String> {
    let path = segment_path(dir, seq);
    fs::metadata(&path)
        .map(|m| m.len())
        .map_err(|e| format!("stat WAL segment {}: {e}", path.display()))
}

/// Read up to `max_len` raw bytes of segment `seq` starting at byte
/// `offset` (0 = include the 6-byte header). Returns the bytes and
/// whether the read reached the CURRENT end of the file — for a sealed
/// segment that is a true EOF; for the active segment it only means
/// "caught up for now". The WAL-shipping replicator streams segments
/// verbatim through this, so a standby's files are byte-identical to
/// the primary's up to the shipped position and replay through the
/// normal [`replay_bounded`] corruption-tolerant walk just works.
pub fn read_segment_chunk(
    dir: &Path,
    seq: u64,
    offset: u64,
    max_len: usize,
) -> Result<(Vec<u8>, bool), String> {
    use std::io::{Seek, SeekFrom};
    let path = segment_path(dir, seq);
    let mut file =
        File::open(&path).map_err(|e| format!("open WAL segment {}: {e}", path.display()))?;
    let len = file
        .metadata()
        .map_err(|e| format!("stat WAL segment {}: {e}", path.display()))?
        .len();
    if offset >= len {
        return Ok((Vec::new(), true));
    }
    file.seek(SeekFrom::Start(offset))
        .map_err(|e| format!("seek WAL segment {}: {e}", path.display()))?;
    let want = ((len - offset) as usize).min(max_len);
    let mut buf = vec![0u8; want];
    let mut read = 0;
    while read < want {
        match file.read(&mut buf[read..]) {
            Ok(0) => break, // concurrent truncation: return what we got
            Ok(n) => read += n,
            Err(e) => return Err(format!("read WAL segment {}: {e}", path.display())),
        }
    }
    buf.truncate(read);
    let eof = offset + read as u64 >= len;
    Ok((buf, eof))
}

/// Appender for one shard's WAL (single-writer: the shard worker).
pub struct WalWriter {
    dir: PathBuf,
    segment_bytes: u64,
    fsync: bool,
    file: File,
    segment: u64,
    offset: u64,
    /// Reused encode scratch (payload bytes).
    scratch: Enc,
    /// Reused frame scratch (length + CRC + payload), so steady-state
    /// appends allocate nothing.
    frame: Vec<u8>,
    appended_bytes: Arc<Counter>,
    fsync_nanos: Arc<Counter>,
    /// Group-commit window (µs); 0 = fsync every append (when `fsync`).
    group_commit_micros: u64,
    /// Appends written since the last sync while grouping.
    dirty_appends: u64,
    /// When the oldest un-synced append of the open group was written.
    group_opened: Option<Instant>,
    group_commits: Arc<Counter>,
    group_appends: Arc<Counter>,
    group_stall_nanos: Arc<Counter>,
}

impl WalWriter {
    /// Open `dir` (created if missing) and start a FRESH segment after
    /// the highest existing one — existing segments are never appended
    /// to, so a recovered process cannot interleave its records with a
    /// crashed predecessor's tail.
    pub fn open(
        dir: &Path,
        segment_bytes: u64,
        fsync: bool,
        appended_bytes: Arc<Counter>,
        fsync_nanos: Arc<Counter>,
    ) -> Result<WalWriter, String> {
        fs::create_dir_all(dir).map_err(|e| format!("create WAL dir {}: {e}", dir.display()))?;
        let segment = list_segments(dir).last().map_or(0, |s| s + 1);
        let (file, offset) = open_segment(dir, segment)?;
        Ok(WalWriter {
            dir: dir.to_path_buf(),
            segment_bytes: segment_bytes.max(HEADER_LEN + 1),
            fsync,
            file,
            segment,
            offset,
            scratch: Enc::new(),
            frame: Vec::new(),
            appended_bytes,
            fsync_nanos,
            group_commit_micros: 0,
            dirty_appends: 0,
            group_opened: None,
            group_commits: Arc::new(Counter::new()),
            group_appends: Arc::new(Counter::new()),
            group_stall_nanos: Arc::new(Counter::new()),
        })
    }

    /// Enable group commit: appends stop fsyncing individually and
    /// instead share one sync per `micros` window (see module docs).
    /// Only meaningful with `fsync = true`; `micros = 0` restores
    /// per-append syncing. The counters record fsyncs issued, appends
    /// covered (size = appends/commits), and oldest-append stall time.
    pub fn set_group_commit(
        &mut self,
        micros: u64,
        commits: Arc<Counter>,
        appends: Arc<Counter>,
        stall_nanos: Arc<Counter>,
    ) {
        self.group_commit_micros = micros;
        self.group_commits = commits;
        self.group_appends = appends;
        self.group_stall_nanos = stall_nanos;
    }

    /// `true` while appends are awaiting a group sync — the shard loop
    /// polls with a timeout instead of blocking indefinitely so an idle
    /// shard still commits within the window.
    pub fn dirty(&self) -> bool {
        self.dirty_appends > 0
    }

    /// The grouping window, when group commit is active.
    pub fn group_window(&self) -> Option<Duration> {
        (self.fsync && self.group_commit_micros > 0)
            .then(|| Duration::from_micros(self.group_commit_micros))
    }

    /// Time until the open group is due (zero when already past due);
    /// `None` when nothing is dirty or grouping is off. The shard loop
    /// uses this as its receive timeout so an idle worker wakes exactly
    /// at the commit deadline.
    pub fn group_due_in(&self) -> Option<Duration> {
        let window = self.group_window()?;
        let opened = self.group_opened?;
        Some(window.saturating_sub(opened.elapsed()))
    }

    /// Sync the open group to disk. `force` commits immediately (Sync
    /// acks, checkpoints); otherwise the sync happens only once the
    /// window has elapsed. Returns whether an fsync was issued. No-op
    /// when nothing is dirty.
    pub fn commit(&mut self, force: bool) -> Result<bool, String> {
        if self.dirty_appends == 0 {
            return Ok(false);
        }
        let window = Duration::from_micros(self.group_commit_micros);
        let due = force || self.group_opened.map_or(true, |t| t.elapsed() >= window);
        if !due {
            return Ok(false);
        }
        self.sync_group()?;
        Ok(true)
    }

    /// Fsync the file and settle the open group's accounting.
    fn sync_group(&mut self) -> Result<(), String> {
        let t0 = Instant::now();
        sync_data_chaos(&self.file).map_err(|e| format!("WAL fsync: {e}"))?;
        self.fsync_nanos.add(t0.elapsed().as_nanos() as u64);
        self.settle_group();
        Ok(())
    }

    /// Record group metrics and reset dirty state (the file is synced —
    /// by [`WalWriter::sync_group`] or a rotation's segment sync).
    fn settle_group(&mut self) {
        if let Some(opened) = self.group_opened.take() {
            self.group_stall_nanos.add(opened.elapsed().as_nanos() as u64);
        }
        self.group_commits.add(1);
        self.group_appends.add(self.dirty_appends);
        self.dirty_appends = 0;
    }

    /// The position the NEXT record will be written at; everything
    /// before it is already durable (modulo OS cache when `fsync` is
    /// off).
    pub fn position(&self) -> WalPosition {
        WalPosition {
            segment: self.segment,
            offset: self.offset,
        }
    }

    /// Append one framed record; rotates to a new segment once the
    /// current one exceeds the configured size.
    pub fn append(&mut self, record: &WalRecord) -> Result<(), String> {
        self.scratch.clear();
        record.encode(&mut self.scratch);
        self.write_framed_scratch()
    }

    /// Hot-path push append: encodes straight from borrowed parts, no
    /// owned [`WalRecord`] (the shard worker calls this once per
    /// accepted message).
    pub fn append_push(&mut self, stream: &str, count: usize, data: &[f64]) -> Result<(), String> {
        self.scratch.clear();
        self.scratch.put_u8(1);
        self.scratch.put_str(stream);
        self.scratch.put_u32(count as u32);
        self.scratch.put_f64_slice(data);
        self.write_framed_scratch()
    }

    /// Borrowed-parts registration append (see [`WalWriter::append_push`]).
    pub fn append_register(&mut self, stream: &str, dim: usize, spec: &str) -> Result<(), String> {
        self.scratch.clear();
        self.scratch.put_u8(2);
        self.scratch.put_str(stream);
        self.scratch.put_u32(dim as u32);
        self.scratch.put_str(spec);
        self.write_framed_scratch()
    }

    /// Borrowed-parts unregistration append.
    pub fn append_unregister(&mut self, stream: &str) -> Result<(), String> {
        self.scratch.clear();
        self.scratch.put_u8(3);
        self.scratch.put_str(stream);
        self.write_framed_scratch()
    }

    /// Frame (`len` + CRC) and write whatever is in the encode scratch,
    /// then fsync/rotate per policy.
    fn write_framed_scratch(&mut self) -> Result<(), String> {
        let payload = self.scratch.as_bytes();
        self.frame.clear();
        self.frame
            .extend_from_slice(&(payload.len() as u32).to_le_bytes());
        self.frame.extend_from_slice(&crc32(payload).to_le_bytes());
        self.frame.extend_from_slice(payload);
        let written = if let Some(torn) = chaos::torn_write(self.frame.len()) {
            // Chaos: leave a genuinely torn prefix on disk — exactly
            // what a crash mid-write leaves — and report the failure.
            let _ = self.file.write_all(&self.frame[..torn]);
            Err(format!(
                "WAL append: chaos tear after {torn}/{} bytes",
                self.frame.len()
            ))
        } else {
            self.file
                .write_all(&self.frame)
                .map_err(|e| format!("WAL append: {e}"))
        };
        if let Err(e) = written {
            return self.heal_torn_tail(e);
        }
        self.offset += self.frame.len() as u64;
        self.appended_bytes.add(self.frame.len() as u64);
        if self.fsync {
            if self.group_commit_micros == 0 {
                let t0 = Instant::now();
                sync_data_chaos(&self.file).map_err(|e| format!("WAL fsync: {e}"))?;
                self.fsync_nanos.add(t0.elapsed().as_nanos() as u64);
            } else {
                // Defer: join (or open) the group; sync only once the
                // window has elapsed so a sustained burst still bounds
                // the oldest append's time-to-durability.
                self.dirty_appends += 1;
                let opened = *self.group_opened.get_or_insert_with(Instant::now);
                if opened.elapsed() >= Duration::from_micros(self.group_commit_micros) {
                    self.sync_group()?;
                }
            }
        }
        if self.offset >= self.segment_bytes {
            self.rotate()?;
        }
        Ok(())
    }

    /// A failed append leaves this segment's tail torn: anything
    /// appended after it would sit past an unparseable frame and be
    /// unreachable at replay. Rotating to a fresh segment restores a
    /// clean frame boundary, bounding the loss to exactly the one
    /// record whose append already failed (and was counted upstream).
    /// Replay skips a sealed segment's corrupt tail and resumes at the
    /// next header ([`ReplaySummary::skipped_tails`]).
    fn heal_torn_tail(&mut self, err: String) -> Result<(), String> {
        match self.rotate() {
            Ok(()) => Err(err),
            Err(rot) => Err(format!("{err}; rotation after torn append failed: {rot}")),
        }
    }

    /// Flush written bytes to the OS (cheap; full durability needs the
    /// `fsync` mode). Called at checkpoint boundaries; settles any open
    /// group first so a checkpoint never records an un-synced position.
    pub fn flush(&mut self) -> Result<(), String> {
        if self.dirty_appends > 0 {
            self.sync_group()?;
        }
        self.file.flush().map_err(|e| format!("WAL flush: {e}"))
    }

    fn rotate(&mut self) -> Result<(), String> {
        // Rotation always syncs the finished segment: a segment that
        // will never be written again should not sit in cache only.
        let t0 = Instant::now();
        let _ = self.file.sync_data();
        self.fsync_nanos.add(t0.elapsed().as_nanos() as u64);
        // That sync also covered any open group on this segment.
        if self.dirty_appends > 0 {
            self.settle_group();
        }
        // Open first, bump after: a failed open must leave the writer
        // consistent (still appending to the old segment), or the
        // reported position would point at a file holding none of the
        // subsequently written bytes.
        let (file, offset) = open_segment(&self.dir, self.segment + 1)?;
        self.segment += 1;
        self.file = file;
        self.offset = offset;
        Ok(())
    }
}

/// `sync_data` with the chaos fsync-fault hook in front (an injected
/// error or stall — one disarmed atomic load in production).
fn sync_data_chaos(file: &File) -> std::io::Result<()> {
    if let Some(e) = chaos::fsync_fault() {
        return Err(e);
    }
    file.sync_data()
}

fn open_segment(dir: &Path, seq: u64) -> Result<(File, u64), String> {
    let path = segment_path(dir, seq);
    let mut file = OpenOptions::new()
        .create(true)
        .append(true)
        .open(&path)
        .map_err(|e| format!("open WAL segment {}: {e}", path.display()))?;
    let mut header = Vec::with_capacity(HEADER_LEN as usize);
    header.extend_from_slice(WAL_MAGIC);
    header.extend_from_slice(&FORMAT_VERSION.to_le_bytes());
    file.write_all(&header)
        .map_err(|e| format!("write WAL header: {e}"))?;
    Ok((file, HEADER_LEN))
}

/// Result of a [`replay`] walk.
#[derive(Clone, Copy, Debug, Default)]
pub struct ReplaySummary {
    /// Records decoded and handed to the callback.
    pub records: u64,
    /// `false` when the walk hit a torn/corrupt record anywhere (the
    /// crash-truncated tail, or a sealed segment's torn tail).
    pub clean: bool,
    /// Corrupt tails of NON-final segments the walk skipped past. A
    /// failed append rotates the writer to a fresh segment
    /// ([`WalWriter`] heals its frame boundary), so a mid-walk tear is
    /// a bounded, already-counted loss — the walk resumes at the next
    /// segment header instead of abandoning every record after it.
    pub skipped_tails: u64,
}

/// Replay every intact record at or after `from`, in order, through
/// `sink`. Corruption (truncated frame, CRC mismatch, undecodable
/// payload, bad segment header) ends the walk cleanly — all records
/// before the corruption point have already been delivered.
pub fn replay(
    dir: &Path,
    from: WalPosition,
    sink: impl FnMut(WalRecord),
) -> Result<ReplaySummary, String> {
    replay_bounded(dir, from, u64::MAX, sink)
}

/// As [`replay`], but ignoring segments past `max_segment` — recovery
/// bounds the walk to the pre-crash layout so it never reads records
/// the replaying coordinator's own fresh WAL writers are appending.
pub fn replay_bounded(
    dir: &Path,
    from: WalPosition,
    max_segment: u64,
    mut sink: impl FnMut(WalRecord),
) -> Result<ReplaySummary, String> {
    let mut summary = ReplaySummary {
        records: 0,
        clean: true,
        skipped_tails: 0,
    };
    let seqs: Vec<u64> = list_segments(dir)
        .into_iter()
        .filter(|&seq| seq >= from.segment && seq <= max_segment)
        .collect();
    for (i, &seq) in seqs.iter().enumerate() {
        let last_segment = i + 1 == seqs.len();
        let path = segment_path(dir, seq);
        let mut bytes = Vec::new();
        File::open(&path)
            .and_then(|mut f| f.read_to_end(&mut bytes))
            .map_err(|e| format!("read WAL segment {}: {e}", path.display()))?;
        // Header check: a foreign or future-format segment ends the walk
        // (the tail past it is unreadable by this build).
        if bytes.len() < HEADER_LEN as usize
            || &bytes[..4] != WAL_MAGIC
            || !(MIN_FORMAT_VERSION..=FORMAT_VERSION)
                .contains(&u16::from_le_bytes([bytes[4], bytes[5]]))
        {
            summary.clean = false;
            return Ok(summary);
        }
        let start = if seq == from.segment {
            // Clamp below to the header (a position of 0 — the
            // no-snapshot recovery fallback — must not parse the magic
            // as a frame) and above to the file length (the crash may
            // have lost cached bytes past the recorded offset).
            (from.offset as usize)
                .max(HEADER_LEN as usize)
                .min(bytes.len())
        } else {
            HEADER_LEN as usize
        };
        let seg = &bytes[start..];
        let mut pos = 0usize;
        // A corrupt record ends THIS segment's walk. In the final
        // segment that is the crash point and the walk is over; in a
        // sealed (non-final) segment it is a torn tail the writer
        // rotated away from — count it and resume at the next segment,
        // so one torn append cannot swallow every record after it.
        let corrupt = |summary: &mut ReplaySummary| {
            summary.clean = false;
            if !last_segment {
                summary.skipped_tails += 1;
            }
        };
        loop {
            if pos == seg.len() {
                break; // clean end of segment
            }
            if seg.len() - pos < 8 {
                corrupt(&mut summary); // torn frame header
                break;
            }
            let len =
                u32::from_le_bytes([seg[pos], seg[pos + 1], seg[pos + 2], seg[pos + 3]]) as usize;
            let want_crc =
                u32::from_le_bytes([seg[pos + 4], seg[pos + 5], seg[pos + 6], seg[pos + 7]]);
            let body = pos + 8;
            if seg.len() - body < len {
                corrupt(&mut summary); // torn payload
                break;
            }
            let payload = &seg[body..body + len];
            if crc32(payload) != want_crc {
                corrupt(&mut summary); // bit flip
                break;
            }
            match WalRecord::decode(&mut Dec::new(payload)) {
                Ok(rec) => {
                    summary.records += 1;
                    sink(rec);
                }
                Err(_) => {
                    corrupt(&mut summary); // undecodable payload
                    break;
                }
            }
            pos = body + len;
        }
    }
    Ok(summary)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::temp_dir;

    fn counters() -> (Arc<Counter>, Arc<Counter>) {
        (Arc::new(Counter::new()), Arc::new(Counter::new()))
    }

    fn push(stream: &str, xs: &[f64]) -> WalRecord {
        WalRecord::Push {
            stream: stream.to_string(),
            count: xs.len(),
            data: xs.to_vec(),
        }
    }

    #[test]
    fn append_replay_roundtrip() {
        let dir = temp_dir("wal-roundtrip");
        let (ab, fs_) = counters();
        let mut w = WalWriter::open(&dir, 1 << 20, false, ab.clone(), fs_).unwrap();
        let start = w.position();
        let records = vec![
            WalRecord::Register {
                stream: "a".into(),
                dim: 2,
                spec: "gea(c=0.5)".into(),
            },
            push("a", &[1.0, 2.0, 3.0, 4.0]),
            push("a", &[5.0, 6.0]),
            WalRecord::Unregister { stream: "a".into() },
        ];
        for r in &records {
            w.append(r).unwrap();
        }
        w.flush().unwrap();
        let mut got = Vec::new();
        let summary = replay(&dir, start, |r| got.push(r)).unwrap();
        assert!(summary.clean);
        assert_eq!(summary.records, records.len() as u64);
        assert_eq!(got, records);
        assert!(ab.get() > 0);
    }

    #[test]
    fn replay_from_mid_position_skips_prefix() {
        let dir = temp_dir("wal-midpos");
        let (ab, fs_) = counters();
        let mut w = WalWriter::open(&dir, 1 << 20, false, ab, fs_).unwrap();
        w.append(&push("a", &[1.0])).unwrap();
        let mid = w.position();
        w.append(&push("a", &[2.0])).unwrap();
        w.flush().unwrap();
        let mut got = Vec::new();
        let summary = replay(&dir, mid, |r| got.push(r)).unwrap();
        assert!(summary.clean);
        assert_eq!(got, vec![push("a", &[2.0])]);
    }

    #[test]
    fn rotation_spans_segments_and_truncation_drops_old_ones() {
        let dir = temp_dir("wal-rotate");
        let (ab, fs_) = counters();
        // Tiny segments: every record rotates.
        let mut w = WalWriter::open(&dir, 16, false, ab, fs_).unwrap();
        let start = w.position();
        for i in 0..10 {
            w.append(&push("s", &[i as f64])).unwrap();
        }
        w.flush().unwrap();
        assert!(list_segments(&dir).len() >= 5, "{:?}", list_segments(&dir));
        let mut got = Vec::new();
        let summary = replay(&dir, start, |r| got.push(r)).unwrap();
        assert!(summary.clean);
        assert_eq!(summary.records, 10);
        // Truncating below the live position keeps the tail replayable.
        let pos = w.position();
        let removed = truncate_before(&dir, pos.segment);
        assert!(removed > 0);
        let mut tail = Vec::new();
        replay(&dir, pos, |r| tail.push(r)).unwrap();
        assert!(tail.is_empty());
    }

    #[test]
    fn reopen_starts_fresh_segment_after_existing() {
        let dir = temp_dir("wal-reopen");
        let (ab, fs_) = counters();
        let mut w = WalWriter::open(&dir, 1 << 20, false, ab, fs_).unwrap();
        let start = w.position();
        assert_eq!(start.segment, 0);
        w.append(&push("a", &[1.0])).unwrap();
        w.flush().unwrap();
        drop(w);
        let (ab2, fs2) = counters();
        let mut w2 = WalWriter::open(&dir, 1 << 20, false, ab2, fs2).unwrap();
        assert_eq!(w2.position().segment, 1);
        w2.append(&push("a", &[2.0])).unwrap();
        w2.flush().unwrap();
        let mut got = Vec::new();
        let summary = replay(&dir, start, |r| got.push(r)).unwrap();
        assert!(summary.clean);
        assert_eq!(got, vec![push("a", &[1.0]), push("a", &[2.0])]);
    }

    #[test]
    fn replay_from_offset_zero_clamps_to_segment_header() {
        // The no-snapshot recovery fallback replays from position
        // {segment: 0, offset: 0}; the walk must skip the 6-byte
        // segment header instead of parsing the magic as a frame.
        let dir = temp_dir("wal-zero-offset");
        let (ab, fs_) = counters();
        let mut w = WalWriter::open(&dir, 1 << 20, false, ab, fs_).unwrap();
        for i in 0..3 {
            w.append(&push("s", &[i as f64])).unwrap();
        }
        w.flush().unwrap();
        let mut got = Vec::new();
        let summary = replay(
            &dir,
            WalPosition {
                segment: 0,
                offset: 0,
            },
            |r| got.push(r),
        )
        .unwrap();
        assert!(summary.clean);
        assert_eq!(summary.records, 3);
        assert_eq!(got[0], push("s", &[0.0]));
    }

    #[test]
    fn group_commit_defers_fsync_and_keeps_bytes_identical() {
        // Same records through per-append fsync and a grouped writer:
        // the on-disk bytes must match exactly (grouping re-times
        // syncs, it never re-frames), and the group metrics must
        // account for every append.
        let per_dir = temp_dir("wal-group-per");
        let grp_dir = temp_dir("wal-group-grp");
        let (ab1, fs1) = counters();
        let (ab2, fs2) = counters();
        let mut per = WalWriter::open(&per_dir, 1 << 20, true, ab1, fs1).unwrap();
        let mut grp = WalWriter::open(&grp_dir, 1 << 20, true, ab2, fs2).unwrap();
        let (commits, appends) = counters();
        let stall = Arc::new(Counter::new());
        // A wide window: nothing syncs until the forced commit below.
        grp.set_group_commit(500_000, commits.clone(), appends.clone(), stall.clone());
        for i in 0..8 {
            let rec = push("s", &[i as f64, 0.5 * i as f64]);
            per.append(&rec).unwrap();
            grp.append(&rec).unwrap();
        }
        assert!(grp.dirty());
        assert!(grp.group_window().is_some());
        // Window not elapsed → unforced commit declines.
        assert!(!grp.commit(false).unwrap());
        assert!(grp.commit(true).unwrap());
        assert!(!grp.dirty());
        assert_eq!(commits.get(), 1);
        assert_eq!(appends.get(), 8);
        assert!(stall.get() > 0);
        let a = fs::read(segment_path(&per_dir, 0)).unwrap();
        let b = fs::read(segment_path(&grp_dir, 0)).unwrap();
        assert_eq!(a, b, "group commit must not change WAL bytes");
        // Replay sees every grouped record.
        let mut n = 0u64;
        let summary = replay(
            &grp_dir,
            WalPosition {
                segment: 0,
                offset: 0,
            },
            |_| n += 1,
        )
        .unwrap();
        assert!(summary.clean);
        assert_eq!(n, 8);
    }

    #[test]
    fn group_commit_settles_on_flush_and_rotation() {
        let dir = temp_dir("wal-group-flush");
        let (ab, fs_) = counters();
        let mut w = WalWriter::open(&dir, 1 << 20, true, ab, fs_).unwrap();
        let (commits, appends) = counters();
        w.set_group_commit(500_000, commits.clone(), appends.clone(), Arc::new(Counter::new()));
        w.append(&push("s", &[1.0])).unwrap();
        assert!(w.dirty());
        // flush (the checkpoint path) must never leave a dirty group.
        w.flush().unwrap();
        assert!(!w.dirty());
        assert_eq!(commits.get(), 1);
        // Tiny segments: rotation's segment sync settles the group too.
        let dir2 = temp_dir("wal-group-rotate");
        let (ab2, fs2) = counters();
        let mut w2 = WalWriter::open(&dir2, 16, true, ab2, fs2).unwrap();
        let (c2, a2) = counters();
        w2.set_group_commit(500_000, c2.clone(), a2.clone(), Arc::new(Counter::new()));
        for i in 0..4 {
            w2.append(&push("s", &[i as f64])).unwrap();
        }
        assert!(!w2.dirty(), "every append rotated, settling its group");
        assert_eq!(a2.get(), 4);
    }

    #[test]
    fn segment_chunks_stream_the_exact_bytes() {
        let dir = temp_dir("wal-chunks");
        let (ab, fs_) = counters();
        let mut w = WalWriter::open(&dir, 1 << 20, false, ab, fs_).unwrap();
        for i in 0..5 {
            w.append(&push("s", &[i as f64, 2.0 * i as f64])).unwrap();
        }
        w.flush().unwrap();
        let pristine = fs::read(segment_path(&dir, 0)).unwrap();
        assert_eq!(segment_len(&dir, 0).unwrap(), pristine.len() as u64);
        // Stream in deliberately awkward 7-byte chunks: reassembly must
        // be byte-identical (frames split mid-record are fine — the
        // standby writes raw bytes, framing is replay's problem).
        let mut shipped = Vec::new();
        let mut off = 0u64;
        loop {
            let (chunk, eof) = read_segment_chunk(&dir, 0, off, 7).unwrap();
            off += chunk.len() as u64;
            shipped.extend_from_slice(&chunk);
            if eof {
                break;
            }
        }
        assert_eq!(shipped, pristine);
        // Reading at/past EOF is an empty caught-up read, not an error.
        let (tail, eof) = read_segment_chunk(&dir, 0, off + 100, 16).unwrap();
        assert!(tail.is_empty() && eof);
        // A missing segment IS an error (the shipper must resync).
        assert!(segment_len(&dir, 99).is_err());
        assert!(read_segment_chunk(&dir, 99, 0, 16).is_err());
    }

    #[test]
    fn corruption_stops_replay_without_losing_prior_records() {
        let dir = temp_dir("wal-corrupt");
        let (ab, fs_) = counters();
        let mut w = WalWriter::open(&dir, 1 << 20, false, ab, fs_).unwrap();
        let start = w.position();
        for i in 0..5 {
            w.append(&push("s", &[i as f64, -(i as f64)])).unwrap();
        }
        w.flush().unwrap();
        let seg = segment_path(&dir, 0);
        let pristine = fs::read(&seg).unwrap();
        // Truncate at EVERY byte offset: replay must never panic and
        // must deliver exactly the records whose frames survived whole.
        for cut in 0..pristine.len() {
            fs::write(&seg, &pristine[..cut]).unwrap();
            let mut n = 0u64;
            let summary = replay(&dir, start, |_| n += 1).unwrap();
            assert_eq!(summary.records, n);
            assert!(n <= 5);
            if cut == pristine.len() - 1 {
                assert!(!summary.clean);
            }
        }
        // Bit flips inside a record body are caught by the CRC.
        let mut flipped = pristine.clone();
        let mid = pristine.len() / 2;
        flipped[mid] ^= 0x10;
        fs::write(&seg, &flipped).unwrap();
        let mut n = 0u64;
        let summary = replay(&dir, start, |_| n += 1).unwrap();
        assert!(!summary.clean);
        assert!(n < 5);
        fs::write(&seg, &pristine).unwrap();
        let summary = replay(&dir, start, |_| {}).unwrap();
        assert!(summary.clean && summary.records == 5);
    }

    #[test]
    fn torn_append_rotates_and_replay_resumes_at_the_next_segment() {
        let _g = chaos::test_mutex().lock().unwrap_or_else(|e| e.into_inner());
        let dir = temp_dir("wal-torn-heal");
        let (ab, fs_) = counters();
        let mut w = WalWriter::open(&dir, 1 << 20, false, ab, fs_).unwrap();
        let start = w.position();
        w.append(&push("s", &[1.0])).unwrap();
        w.append(&push("s", &[2.0])).unwrap();
        chaos::arm(chaos::ChaosPlan {
            seed: 0x70AD,
            torn_write_per_mille: 1000,
            ..Default::default()
        });
        let err = w.append(&push("s", &[3.0])).unwrap_err();
        chaos::disarm();
        assert!(err.contains("chaos tear"), "{err}");
        assert_eq!(chaos::injected(chaos::Site::TornWrite), 1);
        // The writer healed by rotating: later appends land in a fresh
        // segment behind a clean frame boundary.
        assert_eq!(w.position().segment, start.segment + 1);
        w.append(&push("s", &[4.0])).unwrap();
        w.append(&push("s", &[5.0])).unwrap();
        w.flush().unwrap();
        let mut got = Vec::new();
        let summary = replay(&dir, start, |r| got.push(r)).unwrap();
        assert_eq!(summary.records, 4, "only the torn record is lost");
        assert_eq!(
            got,
            vec![
                push("s", &[1.0]),
                push("s", &[2.0]),
                push("s", &[4.0]),
                push("s", &[5.0]),
            ]
        );
        // A zero-length tear leaves segment 0 physically intact; any
        // longer tear leaves a corrupt tail the walk must skip past.
        let torn_bytes: usize = err
            .split("tear after ")
            .nth(1)
            .and_then(|s| s.split('/').next())
            .and_then(|s| s.parse().ok())
            .expect("tear size in error message");
        if torn_bytes > 0 {
            assert!(!summary.clean);
            assert_eq!(summary.skipped_tails, 1);
        } else {
            assert!(summary.clean);
        }
    }

    #[test]
    fn fsync_faults_surface_but_never_wedge_the_writer() {
        let _g = chaos::test_mutex().lock().unwrap_or_else(|e| e.into_inner());
        let dir = temp_dir("wal-fsync-fault");
        let (ab, fs_) = counters();
        // Per-append fsync mode: the injected failure surfaces from the
        // append itself (the bytes are written; durability degraded).
        let mut w = WalWriter::open(&dir, 1 << 20, true, ab, fs_).unwrap();
        let start = w.position();
        chaos::arm(chaos::ChaosPlan {
            seed: 0xF5C,
            fsync_error_per_mille: 1000,
            ..Default::default()
        });
        let err = w.append(&push("s", &[1.0])).unwrap_err();
        assert!(err.contains("fsync"), "{err}");
        chaos::disarm();
        w.append(&push("s", &[2.0])).unwrap();
        // Group-commit mode: the commit fails, the group stays dirty,
        // and the next (healthy) commit settles it.
        let (commits, appends) = counters();
        w.set_group_commit(500_000, commits, appends, Arc::new(Counter::new()));
        w.append(&push("s", &[3.0])).unwrap();
        assert!(w.dirty());
        chaos::arm(chaos::ChaosPlan {
            seed: 0xF5C,
            fsync_error_per_mille: 1000,
            ..Default::default()
        });
        assert!(w.commit(true).is_err());
        chaos::disarm();
        assert!(w.dirty(), "a failed group commit must not drop the group");
        assert!(w.commit(true).unwrap());
        assert!(!w.dirty());
        // Every append made it to disk despite the sync faults.
        let mut n = 0u64;
        let summary = replay(&dir, start, |_| n += 1).unwrap();
        assert!(summary.clean);
        assert_eq!(n, 3);
    }
}
