//! Rendering experiment results: the paper's figures as terminal tables,
//! CSV/JSON dumps, and shape summaries (orderings, ratios, slopes).
//!
//! The acceptance criteria for the reproduction are *shape* claims ("awa3
//! matches true at c=0.5", "expk degrades at k=100"); [`ordering`] and
//! [`ratio_to`] turn curves into those comparable facts, and
//! [`render_curves`] prints the full log–log series the way the paper
//! plots them.

use crate::linreg::ExperimentResult;
use crate::util::fmt::{pad_left, sig4};

/// Render curves as an aligned table: one row per evaluation step, one
/// column per estimator. `max_rows` subsamples long schedules for
/// readability (log-spaced subsample, endpoints kept).
pub fn render_curves(res: &ExperimentResult, max_rows: usize) -> String {
    let mut out = String::new();
    let labels: Vec<&str> = res.curves.iter().map(|c| c.label.as_str()).collect();
    let width = labels.iter().map(|l| l.len()).max().unwrap_or(8).max(10);
    out.push_str(&pad_left("step", 6));
    for l in &labels {
        out.push_str("  ");
        out.push_str(&pad_left(l, width));
    }
    out.push('\n');
    let rows = pick_rows(res.steps.len(), max_rows);
    for &r in &rows {
        out.push_str(&pad_left(&res.steps[r].to_string(), 6));
        for c in &res.curves {
            out.push_str("  ");
            out.push_str(&pad_left(&sig4(c.mean[r]), width));
        }
        out.push('\n');
    }
    out
}

/// CSV dump (full resolution): `step,label1,label2,...`.
pub fn to_csv(res: &ExperimentResult) -> String {
    let mut out = String::from("step");
    for c in &res.curves {
        out.push(',');
        out.push_str(&c.label);
    }
    out.push('\n');
    for (i, &s) in res.steps.iter().enumerate() {
        out.push_str(&s.to_string());
        for c in &res.curves {
            out.push(',');
            out.push_str(&format!("{:e}", c.mean[i]));
        }
        out.push('\n');
    }
    out
}

/// Estimator labels sorted by final excess error (best first) —
/// the "who wins" summary.
pub fn ordering(res: &ExperimentResult) -> Vec<(String, f64)> {
    let mut v: Vec<(String, f64)> = res
        .curves
        .iter()
        .map(|c| (c.label.clone(), c.final_value()))
        .collect();
    v.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap_or(std::cmp::Ordering::Equal));
    v
}

/// Final-value ratio of `label` to the reference curve `reference`
/// (1.0 = identical accuracy; >1 = worse than reference).
pub fn ratio_to(res: &ExperimentResult, label: &str, reference: &str) -> Option<f64> {
    let a = res.curve(label)?.final_value();
    let b = res.curve(reference)?.final_value();
    if b > 0.0 {
        Some(a / b)
    } else {
        None
    }
}

/// Mean ratio of two curves over the tail fraction `tail` of evaluation
/// points — more robust than the single final point.
pub fn tail_ratio(res: &ExperimentResult, label: &str, reference: &str, tail: f64) -> Option<f64> {
    let a = &res.curve(label)?.mean;
    let b = &res.curve(reference)?.mean;
    let start = ((a.len() as f64) * (1.0 - tail)).floor() as usize;
    let start = start.min(a.len() - 1);
    let mut num = 0.0;
    let mut cnt = 0.0;
    for i in start..a.len() {
        if b[i] > 0.0 {
            num += a[i] / b[i];
            cnt += 1.0;
        }
    }
    if cnt > 0.0 {
        Some(num / cnt)
    } else {
        None
    }
}

/// Mean ratio of two curves over an explicit step range `[lo, hi]`
/// (inclusive). The figure-2 claim lives in the *transient* regime
/// (`t ∈ [~2k, ~6k]` for `k = 100`), not the stationary tail, so the
/// benches report this alongside [`tail_ratio`].
pub fn range_ratio(
    res: &ExperimentResult,
    label: &str,
    reference: &str,
    lo: u64,
    hi: u64,
) -> Option<f64> {
    let a = &res.curve(label)?.mean;
    let b = &res.curve(reference)?.mean;
    let mut num = 0.0;
    let mut cnt = 0.0;
    for (i, &t) in res.steps.iter().enumerate() {
        if t >= lo && t <= hi && b[i] > 0.0 {
            num += a[i] / b[i];
            cnt += 1.0;
        }
    }
    if cnt > 0.0 {
        Some(num / cnt)
    } else {
        None
    }
}

/// Least-squares slope of `log(mean)` vs `log(step)` over the last
/// `fraction` of points — the log–log decay rate the figures display.
pub fn loglog_slope(steps: &[u64], mean: &[f64], fraction: f64) -> f64 {
    let n = steps.len();
    let start = ((n as f64) * (1.0 - fraction)).floor() as usize;
    let pts: Vec<(f64, f64)> = (start..n)
        .filter(|&i| mean[i] > 0.0)
        .map(|i| ((steps[i] as f64).ln(), mean[i].ln()))
        .collect();
    if pts.len() < 2 {
        return f64::NAN;
    }
    let m = pts.len() as f64;
    let sx: f64 = pts.iter().map(|p| p.0).sum();
    let sy: f64 = pts.iter().map(|p| p.1).sum();
    let sxx: f64 = pts.iter().map(|p| p.0 * p.0).sum();
    let sxy: f64 = pts.iter().map(|p| p.0 * p.1).sum();
    let denom = m * sxx - sx * sx;
    if denom.abs() < 1e-12 {
        return f64::NAN;
    }
    (m * sxy - sx * sy) / denom
}

/// Render the "who wins" summary with ratios to the best.
pub fn render_summary(res: &ExperimentResult) -> String {
    let ord = ordering(res);
    let best = ord.first().map(|o| o.1).unwrap_or(f64::NAN);
    let mut out = String::from("final excess error (best first):\n");
    for (label, v) in &ord {
        let ratio = if best > 0.0 { v / best } else { f64::NAN };
        out.push_str(&format!(
            "  {:<18} {:>12}   ({:.2}x best)\n",
            label,
            sig4(*v),
            ratio
        ));
    }
    out
}

fn pick_rows(n: usize, max_rows: usize) -> Vec<usize> {
    if n <= max_rows {
        return (0..n).collect();
    }
    // Log-spaced subsample over indices, endpoints included.
    let mut rows: Vec<usize> = (0..max_rows)
        .map(|i| {
            let f = (i as f64) / (max_rows - 1) as f64;
            let x = ((n as f64).ln() * f).exp(); // 1..n
            (x.round() as usize - 1).min(n - 1)
        })
        .collect();
    rows.sort_unstable();
    rows.dedup();
    rows
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linreg::experiment::Curve;
    use std::time::Duration;

    fn fake_result() -> ExperimentResult {
        let steps: Vec<u64> = (1..=100).collect();
        let curve = |label: &str, scale: f64| Curve {
            label: label.to_string(),
            mean: steps.iter().map(|&t| scale / t as f64).collect(),
            stderr: vec![0.0; steps.len()],
        };
        let curves = vec![curve("good", 1.0), curve("bad", 3.0)];
        ExperimentResult {
            steps,
            curves,
            runs: 1,
            wall: Duration::from_secs(0),
        }
    }

    #[test]
    fn ordering_sorts_by_final() {
        let res = fake_result();
        let ord = ordering(&res);
        assert_eq!(ord[0].0, "good");
        assert_eq!(ord[1].0, "bad");
    }

    #[test]
    fn ratio_and_tail_ratio() {
        let res = fake_result();
        assert!((ratio_to(&res, "bad", "good").unwrap() - 3.0).abs() < 1e-12);
        assert!((tail_ratio(&res, "bad", "good", 0.3).unwrap() - 3.0).abs() < 1e-12);
        assert!((range_ratio(&res, "bad", "good", 20, 60).unwrap() - 3.0).abs() < 1e-12);
        assert!(range_ratio(&res, "bad", "good", 2000, 3000).is_none());
    }

    #[test]
    fn slope_of_one_over_t_is_minus_one() {
        let res = fake_result();
        let s = loglog_slope(&res.steps, &res.curves[0].mean, 0.8);
        assert!((s + 1.0).abs() < 1e-9, "slope={s}");
    }

    #[test]
    fn render_outputs_all_columns() {
        let res = fake_result();
        let table = render_curves(&res, 10);
        assert!(table.contains("good"));
        assert!(table.contains("bad"));
        assert!(table.lines().count() <= 12);
        let summary = render_summary(&res);
        assert!(summary.contains("1.00x best"));
        assert!(summary.contains("3.00x best"));
    }

    #[test]
    fn csv_has_header_and_rows() {
        let res = fake_result();
        let csv = to_csv(&res);
        let mut lines = csv.lines();
        assert_eq!(lines.next().unwrap(), "step,good,bad");
        assert_eq!(csv.lines().count(), 101);
    }

    #[test]
    fn pick_rows_endpoints() {
        let rows = pick_rows(1000, 20);
        assert_eq!(*rows.first().unwrap(), 0);
        assert_eq!(*rows.last().unwrap(), 999);
        assert!(rows.len() <= 20);
    }
}
