//! Gaussian sampling on top of any [`RngCore`].

use super::RngCore;

/// Standard-normal sampler using the Marsaglia polar method with a cached
/// spare deviate.
///
/// The polar method needs no `ln`/`cos` pairing tricks and produces two
/// independent N(0,1) deviates per acceptance; we cache the second. This is
/// the generator behind all data sampling in the linear-regression workload
/// ([`crate::linreg`]), so it carries unit tests for moments and tails.
#[derive(Clone, Debug)]
pub struct GaussianSource<R: RngCore> {
    rng: R,
    spare: Option<f64>,
}

impl<R: RngCore> GaussianSource<R> {
    /// Wrap a uniform generator.
    pub fn new(rng: R) -> Self {
        Self { rng, spare: None }
    }

    /// Access the underlying uniform generator.
    pub fn rng_mut(&mut self) -> &mut R {
        &mut self.rng
    }

    /// One N(0, 1) deviate.
    pub fn next_gaussian(&mut self) -> f64 {
        if let Some(s) = self.spare.take() {
            return s;
        }
        loop {
            // u, v uniform on (-1, 1)
            let u = 2.0 * self.rng.next_f64() - 1.0;
            let v = 2.0 * self.rng.next_f64() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                let factor = (-2.0 * s.ln() / s).sqrt();
                self.spare = Some(v * factor);
                return u * factor;
            }
        }
    }

    /// One N(mean, std²) deviate.
    #[inline]
    pub fn next_gaussian_with(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.next_gaussian()
    }

    /// Fill `out` with independent N(0,1) deviates.
    pub fn fill_standard(&mut self, out: &mut [f64]) {
        for o in out.iter_mut() {
            *o = self.next_gaussian();
        }
    }

    /// Fill `out[i] ~ N(0, scales[i]²)` — a diagonal-covariance draw.
    ///
    /// This is the exact sampler for the paper's covariates `x ~ N(0, H)`
    /// with `H = diag(h_i)`: pass `scales[i] = sqrt(h_i)`.
    pub fn fill_diag(&mut self, scales: &[f64], out: &mut [f64]) {
        assert_eq!(scales.len(), out.len());
        for (o, &s) in out.iter_mut().zip(scales) {
            *o = s * self.next_gaussian();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256;

    fn source(seed: u64) -> GaussianSource<Xoshiro256> {
        GaussianSource::new(Xoshiro256::seed_from_u64(seed))
    }

    #[test]
    fn moments_match_standard_normal() {
        let mut g = source(42);
        let n = 200_000;
        let (mut m1, mut m2, mut m4) = (0.0, 0.0, 0.0);
        for _ in 0..n {
            let x = g.next_gaussian();
            m1 += x;
            m2 += x * x;
            m4 += x * x * x * x;
        }
        let n = n as f64;
        m1 /= n;
        m2 /= n;
        m4 /= n;
        assert!(m1.abs() < 0.01, "mean={m1}");
        assert!((m2 - 1.0).abs() < 0.02, "var={m2}");
        assert!((m4 - 3.0).abs() < 0.1, "kurtosis*3={m4}");
    }

    #[test]
    fn tail_mass_is_plausible() {
        let mut g = source(7);
        let n = 100_000;
        let beyond_2 = (0..n).filter(|_| g.next_gaussian().abs() > 2.0).count();
        let frac = beyond_2 as f64 / n as f64;
        // P(|Z| > 2) ≈ 0.0455
        assert!((frac - 0.0455).abs() < 0.006, "frac={frac}");
    }

    #[test]
    fn scaled_moments() {
        let mut g = source(3);
        let n = 100_000;
        let mut m1 = 0.0;
        let mut m2 = 0.0;
        for _ in 0..n {
            let x = g.next_gaussian_with(5.0, 0.5);
            m1 += x;
            m2 += (x - 5.0) * (x - 5.0);
        }
        m1 /= n as f64;
        m2 /= n as f64;
        assert!((m1 - 5.0).abs() < 0.01, "mean={m1}");
        assert!((m2 - 0.25).abs() < 0.01, "var={m2}");
    }

    #[test]
    fn fill_diag_scales_each_coordinate() {
        let mut g = source(9);
        let scales: Vec<f64> = (1..=8).map(|i| 1.0 / (i as f64).sqrt()).collect();
        let d = scales.len();
        let n = 50_000;
        let mut var = vec![0.0f64; d];
        let mut buf = vec![0.0f64; d];
        for _ in 0..n {
            g.fill_diag(&scales, &mut buf);
            for (v, &x) in var.iter_mut().zip(&buf) {
                *v += x * x;
            }
        }
        for (i, v) in var.iter().enumerate() {
            let got = v / n as f64;
            let want = scales[i] * scales[i];
            assert!(
                (got - want).abs() < 0.05 * want.max(0.05),
                "coord {i}: got {got}, want {want}"
            );
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let mut a = source(1);
        let mut b = source(1);
        for _ in 0..64 {
            assert_eq!(a.next_gaussian(), b.next_gaussian());
        }
    }
}
