//! Deterministic pseudo-random number generation.
//!
//! The offline build environment does not ship the `rand` crate, and the
//! experiments need *reproducible, splittable* randomness (100 independent
//! runs, each with independent data streams, re-runnable bit-for-bit), so we
//! implement the generators ourselves:
//!
//! * [`SplitMix64`] — the seeding/stream-splitting generator (Steele et al.,
//!   2014). Used to expand a single `u64` seed into generator states and to
//!   derive independent substreams.
//! * [`Xoshiro256`] — xoshiro256++ (Blackman & Vigna, 2019), the workhorse
//!   uniform generator: 256-bit state, sub-ns step, passes BigCrush.
//! * Gaussian sampling via the polar (Marsaglia) method with a cached spare,
//!   plus vectorized helpers for the diagonal-covariance draws the
//!   linear-regression workload needs.

mod gaussian;
mod splitmix;
mod xoshiro;

pub use gaussian::GaussianSource;
pub use splitmix::SplitMix64;
pub use xoshiro::Xoshiro256;

/// A uniform random bit source.
///
/// Implemented by both [`SplitMix64`] and [`Xoshiro256`]; all higher-level
/// sampling (uniform floats, gaussians, permutations) is generic over it.
pub trait RngCore {
    /// Next 64 uniformly distributed bits.
    fn next_u64(&mut self) -> u64;

    /// Uniform `f64` in `[0, 1)` with 53 bits of precision.
    #[inline]
    fn next_f64(&mut self) -> f64 {
        // Take the top 53 bits — the low bits of some generators are weaker.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform `f32` in `[0, 1)` with 24 bits of precision.
    #[inline]
    fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in `[0, n)` via Lemire's multiply-shift rejection.
    fn next_below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "next_below(0)");
        // 128-bit multiply rejection sampling: unbiased.
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }
}

/// Fisher–Yates shuffle of a slice.
pub fn shuffle<T, R: RngCore>(rng: &mut R, xs: &mut [T]) {
    let n = xs.len();
    for i in (1..n).rev() {
        let j = rng.next_below((i + 1) as u64) as usize;
        xs.swap(i, j);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn next_f64_in_unit_interval() {
        let mut rng = Xoshiro256::seed_from_u64(42);
        for _ in 0..10_000 {
            let u = rng.next_f64();
            assert!((0.0..1.0).contains(&u), "u={u}");
        }
    }

    #[test]
    fn next_f32_in_unit_interval() {
        let mut rng = Xoshiro256::seed_from_u64(7);
        for _ in 0..10_000 {
            let u = rng.next_f32();
            assert!((0.0..1.0).contains(&u), "u={u}");
        }
    }

    #[test]
    fn next_below_bounds_and_coverage() {
        let mut rng = Xoshiro256::seed_from_u64(1);
        let n = 13u64;
        let mut seen = vec![false; n as usize];
        for _ in 0..10_000 {
            let v = rng.next_below(n);
            assert!(v < n);
            seen[v as usize] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues hit");
    }

    #[test]
    fn next_below_one_is_zero() {
        let mut rng = Xoshiro256::seed_from_u64(1);
        for _ in 0..100 {
            assert_eq!(rng.next_below(1), 0);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Xoshiro256::seed_from_u64(3);
        let mut xs: Vec<u32> = (0..100).collect();
        shuffle(&mut rng, &mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        // Overwhelmingly unlikely to be identity.
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn uniform_mean_close_to_half() {
        let mut rng = Xoshiro256::seed_from_u64(99);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| rng.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.005, "mean={mean}");
    }
}
