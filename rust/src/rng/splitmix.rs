//! SplitMix64 — seeding and stream-splitting generator.

use super::RngCore;

/// SplitMix64 (Steele, Lea & Flood, 2014).
///
/// A tiny, fast, well-distributed 64-bit generator whose state is a single
/// counter. It is *not* the workhorse generator (period 2^64, weaker
/// equidistribution than xoshiro) but it is ideal for two jobs:
///
/// 1. expanding a user-provided `u64` seed into the 256-bit state of
///    [`super::Xoshiro256`], and
/// 2. deriving independent substreams: `SplitMix64::new(seed).split(i)`
///    gives stream `i` a state far from stream `j`'s for `i != j`.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Create from a raw seed.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Derive the state for substream `index` without perturbing `self`.
    ///
    /// Uses the golden-gamma increment scaled by a mixed index so that
    /// consecutive indices land in distant regions of the state space.
    pub fn split(&self, index: u64) -> SplitMix64 {
        let mut mixer = SplitMix64::new(self.state ^ mix(index));
        // Burn a few outputs so trivially related seeds decorrelate.
        mixer.next_u64();
        mixer.next_u64();
        SplitMix64::new(mixer.next_u64())
    }
}

/// The SplitMix64 finalizer (variant 13 of Stafford's mixers).
#[inline]
fn mix(mut z: u64) -> u64 {
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl RngCore for SplitMix64 {
    #[inline]
    fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        mix(self.state)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_answer_vector() {
        // Reference values for seed 0 (from the public-domain C reference).
        let mut rng = SplitMix64::new(0);
        let expected: [u64; 4] = [
            0xE220_A839_7B1D_CDAF,
            0x6E78_9E6A_A1B9_65F4,
            0x06C4_5D18_8009_454F,
            0xF88B_B8A8_724C_81EC,
        ];
        for e in expected {
            assert_eq!(rng.next_u64(), e);
        }
    }

    #[test]
    fn split_streams_differ() {
        let root = SplitMix64::new(1234);
        let mut a = root.split(0);
        let mut b = root.split(1);
        let va: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(va, vb);
    }

    #[test]
    fn split_is_deterministic() {
        let root = SplitMix64::new(99);
        let mut a1 = root.split(7);
        let mut a2 = root.split(7);
        for _ in 0..16 {
            assert_eq!(a1.next_u64(), a2.next_u64());
        }
    }
}
