//! xoshiro256++ — the workhorse uniform generator.

use super::{RngCore, SplitMix64};

/// xoshiro256++ 1.0 (Blackman & Vigna, 2019).
///
/// 256-bit state, period `2^256 − 1`, passes BigCrush/PractRand; the
/// recommended general-purpose generator of the xoshiro family. State must
/// never be all-zero, which [`Xoshiro256::seed_from_u64`] guarantees by
/// seeding through SplitMix64.
#[derive(Clone, Debug)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    /// Seed by expanding `seed` through SplitMix64 (the reference-
    /// recommended procedure).
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let s = [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()];
        Self { s }
    }

    /// Seed substream `index` of a root seed: distinct indices yield
    /// decorrelated streams (used for parallel experiment runs).
    pub fn substream(root_seed: u64, index: u64) -> Self {
        let sm = SplitMix64::new(root_seed).split(index);
        let mut sm = sm;
        let s = [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()];
        Self { s }
    }

    /// The `jump()` function from the reference implementation: advances the
    /// state by `2^128` steps, equivalent to generating `2^128` outputs.
    /// Useful for carving one long stream into guaranteed-disjoint blocks.
    pub fn jump(&mut self) {
        const JUMP: [u64; 4] = [
            0x180E_C6D3_3CFD_0ABA,
            0xD5A6_1266_F0C9_392C,
            0xA958_2618_E03F_C9AA,
            0x39AB_DC45_29B1_661C,
        ];
        let mut s0 = 0u64;
        let mut s1 = 0u64;
        let mut s2 = 0u64;
        let mut s3 = 0u64;
        for j in JUMP {
            for b in 0..64 {
                if (j & (1u64 << b)) != 0 {
                    s0 ^= self.s[0];
                    s1 ^= self.s[1];
                    s2 ^= self.s[2];
                    s3 ^= self.s[3];
                }
                self.next_u64();
            }
        }
        self.s = [s0, s1, s2, s3];
    }
}

impl RngCore for Xoshiro256 {
    #[inline]
    fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_answer_vector() {
        // Reference output of xoshiro256++ with state {1,2,3,4}
        // (public-domain C reference, first 8 outputs).
        let mut rng = Xoshiro256 { s: [1, 2, 3, 4] };
        let expected: [u64; 8] = [
            41943041,
            58720359,
            3588806011781223,
            3591011842654386,
            9228616714210784205,
            9973669472204895162,
            14011001112246962877,
            12406186145184390807,
        ];
        for e in expected {
            assert_eq!(rng.next_u64(), e);
        }
    }

    #[test]
    fn substreams_decorrelate() {
        let mut a = Xoshiro256::substream(5, 0);
        let mut b = Xoshiro256::substream(5, 1);
        let mut equal = 0;
        for _ in 0..1000 {
            if a.next_u64() == b.next_u64() {
                equal += 1;
            }
        }
        assert_eq!(equal, 0);
    }

    #[test]
    fn jump_changes_state() {
        let mut a = Xoshiro256::seed_from_u64(11);
        let mut b = a.clone();
        b.jump();
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn deterministic_per_seed() {
        let mut a = Xoshiro256::seed_from_u64(123);
        let mut b = Xoshiro256::seed_from_u64(123);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }
}
