//! Artifact manifest: what `python/compile/aot.py` produced.

use crate::util::json::Json;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Shape + dtype of one tensor as recorded in `manifest.json`.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    /// Numpy dtype name (only `float32` is produced today).
    pub dtype: String,
    pub dims: Vec<usize>,
}

impl TensorSpec {
    /// Total element count.
    pub fn elements(&self) -> usize {
        self.dims.iter().product()
    }

    fn from_json(j: &Json) -> Result<TensorSpec, String> {
        let arr = j.as_arr().ok_or("tensor spec must be [dtype, dims]")?;
        if arr.len() != 2 {
            return Err("tensor spec must be [dtype, dims]".into());
        }
        let dtype = arr[0]
            .as_str()
            .ok_or("tensor dtype must be a string")?
            .to_string();
        let dims = arr[1]
            .as_arr()
            .ok_or("tensor dims must be an array")?
            .iter()
            .map(|d| {
                d.as_u64()
                    .map(|v| v as usize)
                    .ok_or_else(|| "dims must be nonnegative integers".to_string())
            })
            .collect::<Result<Vec<_>, _>>()?;
        Ok(TensorSpec { dtype, dims })
    }
}

/// One AOT entry point.
#[derive(Clone, Debug)]
pub struct EntrySpec {
    pub name: String,
    /// HLO text file, relative to the artifacts dir.
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    pub sha256: Option<String>,
}

/// Parsed `artifacts/manifest.json`.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub entries: BTreeMap<String, EntrySpec>,
}

impl Manifest {
    /// Load from an artifacts directory.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest, String> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| format!("cannot read {}: {e} (run `make artifacts`)", path.display()))?;
        Self::parse(&text, dir)
    }

    /// Parse manifest text (exposed for tests).
    pub fn parse(text: &str, dir: PathBuf) -> Result<Manifest, String> {
        let doc = Json::parse(text).map_err(|e| e.to_string())?;
        let entries_json = doc
            .get("entries")
            .and_then(Json::as_obj)
            .ok_or("manifest missing 'entries' object")?;
        let mut entries = BTreeMap::new();
        for (name, e) in entries_json {
            let file = e
                .get("file")
                .and_then(Json::as_str)
                .ok_or_else(|| format!("entry '{name}' missing 'file'"))?
                .to_string();
            let parse_specs = |key: &str| -> Result<Vec<TensorSpec>, String> {
                e.get(key)
                    .and_then(Json::as_arr)
                    .ok_or_else(|| format!("entry '{name}' missing '{key}'"))?
                    .iter()
                    .map(TensorSpec::from_json)
                    .collect()
            };
            entries.insert(
                name.clone(),
                EntrySpec {
                    name: name.clone(),
                    file,
                    inputs: parse_specs("inputs")?,
                    outputs: parse_specs("outputs")?,
                    sha256: e.get("sha256").and_then(Json::as_str).map(String::from),
                },
            );
        }
        if entries.is_empty() {
            return Err("manifest has no entries".into());
        }
        Ok(Manifest { dir, entries })
    }

    /// Entry lookup with a helpful error.
    pub fn entry(&self, name: &str) -> Result<&EntrySpec, String> {
        self.entries.get(name).ok_or_else(|| {
            format!(
                "no artifact '{name}'; available: {:?}",
                self.entries.keys().collect::<Vec<_>>()
            )
        })
    }

    /// Absolute path of an entry's HLO file.
    pub fn hlo_path(&self, entry: &EntrySpec) -> PathBuf {
        self.dir.join(&entry.file)
    }

    /// Find an entry by prefix (e.g. `sgd_chunk` regardless of shapes).
    pub fn entry_by_prefix(&self, prefix: &str) -> Result<&EntrySpec, String> {
        let mut matches: Vec<&EntrySpec> = self
            .entries
            .values()
            .filter(|e| e.name.starts_with(prefix))
            .collect();
        match matches.len() {
            0 => Err(format!("no artifact starting with '{prefix}'")),
            1 => Ok(matches.remove(0)),
            n => Err(format!("{n} artifacts start with '{prefix}'; be specific")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "entries": {
        "sgd_step_d6_b2": {
          "file": "sgd_step_d6_b2.hlo.txt",
          "inputs": [["float32",[6]],["float32",[2,6]],["float32",[2]],["float32",[1]]],
          "outputs": [["float32",[6]]],
          "sha256": "abc"
        },
        "sgd_chunk_d6_b2_s3": {
          "file": "c.hlo.txt",
          "inputs": [["float32",[6]],["float32",[3,2,6]],["float32",[3,2]],["float32",[1]]],
          "outputs": [["float32",[6]],["float32",[3,6]]]
        }
      },
      "format": "hlo-text"
    }"#;

    #[test]
    fn parses_entries_and_specs() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp/a")).unwrap();
        assert_eq!(m.entries.len(), 2);
        let e = m.entry("sgd_step_d6_b2").unwrap();
        assert_eq!(e.inputs.len(), 4);
        assert_eq!(e.inputs[1].dims, vec![2, 6]);
        assert_eq!(e.inputs[1].elements(), 12);
        assert_eq!(e.outputs[0].dims, vec![6]);
        assert_eq!(e.sha256.as_deref(), Some("abc"));
        assert_eq!(
            m.hlo_path(e),
            PathBuf::from("/tmp/a/sgd_step_d6_b2.hlo.txt")
        );
    }

    #[test]
    fn prefix_lookup() {
        let m = Manifest::parse(SAMPLE, PathBuf::from(".")).unwrap();
        assert!(m.entry_by_prefix("sgd_chunk").is_ok());
        assert!(m.entry_by_prefix("sgd").is_err()); // ambiguous
        assert!(m.entry_by_prefix("nope").is_err());
    }

    #[test]
    fn missing_entry_error_lists_available() {
        let m = Manifest::parse(SAMPLE, PathBuf::from(".")).unwrap();
        let err = m.entry("zzz").unwrap_err();
        assert!(err.contains("sgd_step_d6_b2"));
    }

    #[test]
    fn rejects_malformed() {
        assert!(Manifest::parse("{}", PathBuf::from(".")).is_err());
        assert!(Manifest::parse(r#"{"entries":{}}"#, PathBuf::from(".")).is_err());
        assert!(Manifest::parse(
            r#"{"entries":{"x":{"file":"f"}}}"#,
            PathBuf::from(".")
        )
        .is_err());
    }
}
