//! PJRT client wrapper and typed execution of AOT entries.

use super::artifact::{EntrySpec, Manifest};
use crate::metrics::Registry;
use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::Instant;

/// A compiled entry point: the PJRT executable plus its manifest spec.
pub struct CompiledEntry {
    spec: EntrySpec,
    exe: xla::PjRtLoadedExecutable,
}

impl CompiledEntry {
    /// The manifest spec (shapes) of this entry.
    pub fn spec(&self) -> &EntrySpec {
        &self.spec
    }

    /// Execute with row-major `f32` buffers; returns one buffer per
    /// output. Input lengths are validated against the manifest.
    pub fn call(&self, inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>, String> {
        if inputs.len() != self.spec.inputs.len() {
            return Err(format!(
                "{}: expected {} inputs, got {}",
                self.spec.name,
                self.spec.inputs.len(),
                inputs.len()
            ));
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (i, (buf, tspec)) in inputs.iter().zip(&self.spec.inputs).enumerate() {
            if buf.len() != tspec.elements() {
                return Err(format!(
                    "{}: input {i} has {} elements, manifest says {:?} ({})",
                    self.spec.name,
                    buf.len(),
                    tspec.dims,
                    tspec.elements()
                ));
            }
            let dims: Vec<i64> = tspec.dims.iter().map(|&d| d as i64).collect();
            let lit = xla::Literal::vec1(buf)
                .reshape(&dims)
                .map_err(|e| format!("{}: reshape input {i}: {e}", self.spec.name))?;
            literals.push(lit);
        }
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| format!("{}: execute: {e}", self.spec.name))?;
        let root = result[0][0]
            .to_literal_sync()
            .map_err(|e| format!("{}: readback: {e}", self.spec.name))?;
        // aot.py lowers with return_tuple=True: the root is always a tuple.
        let parts = root
            .to_tuple()
            .map_err(|e| format!("{}: tuple unwrap: {e}", self.spec.name))?;
        if parts.len() != self.spec.outputs.len() {
            return Err(format!(
                "{}: manifest promises {} outputs, module returned {}",
                self.spec.name,
                self.spec.outputs.len(),
                parts.len()
            ));
        }
        let mut out = Vec::with_capacity(parts.len());
        for (i, (part, tspec)) in parts.into_iter().zip(&self.spec.outputs).enumerate() {
            let v: Vec<f32> = part
                .to_vec()
                .map_err(|e| format!("{}: output {i} to_vec: {e}", self.spec.name))?;
            if v.len() != tspec.elements() {
                return Err(format!(
                    "{}: output {i} has {} elements, manifest says {}",
                    self.spec.name,
                    v.len(),
                    tspec.elements()
                ));
            }
            out.push(v);
        }
        Ok(out)
    }
}

/// PJRT CPU runtime with a lazily populated executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: Mutex<BTreeMap<String, std::sync::Arc<CompiledEntry>>>,
    metrics: Registry,
}

impl Runtime {
    /// Create over a loaded manifest.
    pub fn new(manifest: Manifest) -> Result<Runtime, String> {
        let client = xla::PjRtClient::cpu().map_err(|e| format!("pjrt cpu client: {e}"))?;
        crate::log_info!(
            "runtime",
            "PJRT client up: platform={} devices={}",
            client.platform_name(),
            client.device_count()
        );
        Ok(Runtime {
            client,
            manifest,
            cache: Mutex::new(BTreeMap::new()),
            metrics: Registry::new(),
        })
    }

    /// Convenience: load the manifest from `dir` and build the runtime.
    pub fn from_dir(dir: &str) -> Result<Runtime, String> {
        Runtime::new(Manifest::load(dir)?)
    }

    /// The manifest in use.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Runtime metrics (compile count/time, call count/time).
    pub fn metrics(&self) -> &Registry {
        &self.metrics
    }

    /// Get (compiling on first use) an entry point.
    pub fn load(&self, name: &str) -> Result<std::sync::Arc<CompiledEntry>, String> {
        {
            let cache = self.cache.lock().expect("runtime cache");
            if let Some(e) = cache.get(name) {
                return Ok(e.clone());
            }
        }
        let spec = self.manifest.entry(name)?.clone();
        let path = self.manifest.hlo_path(&spec);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or("non-utf8 artifact path")?,
        )
        .map_err(|e| format!("{name}: parse HLO text {}: {e}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| format!("{name}: XLA compile: {e}"))?;
        let compile_ms = t0.elapsed().as_millis();
        crate::log_info!("runtime", "compiled {name} in {compile_ms}ms");
        self.metrics.counter("compiles").inc();
        self.metrics
            .histogram("compile_ms")
            .record(compile_ms as u64);
        let entry = std::sync::Arc::new(CompiledEntry { spec, exe });
        let mut cache = self.cache.lock().expect("runtime cache");
        Ok(cache.entry(name.to_string()).or_insert(entry).clone())
    }

    /// One-shot: load (cached) and call.
    pub fn call(&self, name: &str, inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>, String> {
        let entry = self.load(name)?;
        let t0 = Instant::now();
        let out = entry.call(inputs)?;
        self.metrics.counter("calls").inc();
        self.metrics
            .histogram("call_us")
            .record(t0.elapsed().as_micros() as u64);
        Ok(out)
    }
}

// Unit tests for the runtime need real artifacts; they live in
// rust/tests/runtime_roundtrip.rs and skip (with a notice) when
// `make artifacts` has not run.
