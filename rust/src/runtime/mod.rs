//! PJRT runtime: load and execute the AOT artifacts from Rust.
//!
//! `make artifacts` (the only place Python runs) lowers the L2 graphs to
//! HLO text; this module compiles them once on the PJRT CPU client and
//! executes them from the coordinator hot path:
//!
//! ```text
//! Manifest::load("artifacts")          — what was exported, with shapes
//!   └─ Runtime::new(manifest)          — PJRT client + executable cache
//!        └─ rt.call("sgd_step_d50_b11", &[w, x, y, eta])  — Vec<f32> I/O
//! ```
//!
//! All tensors are `f32` row-major; shapes are validated against the
//! manifest before every call so a drifted artifact fails loudly, not
//! numerically.

mod artifact;
// The real executor binds to the offline-vendored `xla` crate; when the
// `xla` cargo feature is off (the default in environments without the
// vendored crate) an API-compatible stub takes its place whose
// constructors report the missing backend.
#[cfg(feature = "xla")]
mod exec;
#[cfg(not(feature = "xla"))]
#[path = "stub.rs"]
mod exec;

pub use artifact::{EntrySpec, Manifest, TensorSpec};
pub use exec::{CompiledEntry, Runtime};

/// The default artifacts directory (crate-root relative).
pub const DEFAULT_ARTIFACTS_DIR: &str = "artifacts";

/// True when the artifacts directory exists with a manifest — used by
/// tests/examples to skip gracefully with a pointer to `make artifacts`.
pub fn artifacts_available(dir: &str) -> bool {
    std::path::Path::new(dir).join("manifest.json").exists()
}
