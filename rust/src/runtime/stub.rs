//! Stub executor used when the crate is built WITHOUT the `xla` feature.
//!
//! The PJRT bindings come from an offline-vendored `xla` crate that is
//! not present in every build environment. This stub keeps the
//! [`crate::runtime`] API shape — manifests still load and validate — so
//! the launcher, examples and tests compile and degrade gracefully;
//! every execution entry point returns an explanatory error instead.

use super::artifact::{EntrySpec, Manifest};
use crate::metrics::Registry;
use std::sync::Arc;

const NO_BACKEND: &str = "PJRT backend unavailable: built without the `xla` cargo feature \
     (the vendored xla crate is not present in this build). Rebuild with \
     `--features xla` to compile and execute AOT artifacts.";

/// Stub compiled entry: never constructed (the stub [`Runtime`] cannot
/// be built), present only to keep caller signatures compiling.
pub struct CompiledEntry {
    spec: EntrySpec,
}

impl CompiledEntry {
    /// The manifest spec (shapes) of this entry.
    pub fn spec(&self) -> &EntrySpec {
        &self.spec
    }

    /// Always errors: no backend to execute on.
    pub fn call(&self, _inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>, String> {
        Err(NO_BACKEND.to_string())
    }
}

/// Stub runtime: construction always fails with a pointer to the
/// missing `xla` feature.
pub struct Runtime {
    manifest: Manifest,
    metrics: Registry,
}

impl Runtime {
    /// Always errors (no PJRT client without the `xla` feature).
    pub fn new(_manifest: Manifest) -> Result<Runtime, String> {
        Err(NO_BACKEND.to_string())
    }

    /// Loads (and validates) the manifest, then fails like [`Runtime::new`].
    pub fn from_dir(dir: &str) -> Result<Runtime, String> {
        Runtime::new(Manifest::load(dir)?)
    }

    /// The manifest in use.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Runtime metrics registry.
    pub fn metrics(&self) -> &Registry {
        &self.metrics
    }

    /// Always errors: no backend to compile on.
    pub fn load(&self, _name: &str) -> Result<Arc<CompiledEntry>, String> {
        Err(NO_BACKEND.to_string())
    }

    /// Always errors: no backend to execute on.
    pub fn call(&self, _name: &str, _inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>, String> {
        Err(NO_BACKEND.to_string())
    }
}
