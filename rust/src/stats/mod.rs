//! Streaming statistics built on the averagers.
//!
//! * [`RunningStats`] — Welford mean/variance/min/max of a scalar stream
//!   (used by metrics and benches).
//! * [`MomentTracker`] — the paper-conclusion use case: BatchNorm-style
//!   tracking of per-unit activation mean and variance where the averaging
//!   window *grows* as training stabilizes, powered by any
//!   [`crate::averagers::Averager`].

use crate::averagers::{Averager, AveragerSpec};

/// Numerically stable running scalar statistics (Welford).
#[derive(Clone, Debug, Default)]
pub struct RunningStats {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl RunningStats {
    pub fn new() -> RunningStats {
        RunningStats {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.mean
        }
    }

    /// Population variance.
    pub fn variance(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.m2 / self.n as f64
        }
    }

    /// Sample variance (n−1 denominator).
    pub fn sample_variance(&self) -> f64 {
        if self.n < 2 {
            f64::NAN
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    /// Merge another accumulator into this one (parallel reduction).
    pub fn merge(&mut self, other: &RunningStats) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n1 = self.n as f64;
        let n2 = other.n as f64;
        let delta = other.mean - self.mean;
        let n = n1 + n2;
        self.mean += delta * n2 / n;
        self.m2 += other.m2 + delta * delta * n1 * n2 / n;
        self.n += other.n;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// BatchNorm-style tracker of per-coordinate mean and variance of an
/// activation stream, using a configurable tail-averaging estimator for
/// both the first and second moment.
///
/// The paper's conclusion proposes exactly this: replace BatchNorm's fixed
/// EMA with a growing-window estimator ([`AveragerSpec::Gea`]) so that the
/// statistics are estimated over ever-longer horizons as optimization
/// stabilizes.
pub struct MomentTracker {
    mean_avg: Box<dyn Averager>,
    sq_avg: Box<dyn Averager>,
    sq_buf: Vec<f64>,
    d: usize,
}

impl MomentTracker {
    pub fn new(d: usize, spec: &AveragerSpec) -> Result<MomentTracker, String> {
        Ok(MomentTracker {
            mean_avg: spec.build(d)?,
            sq_avg: spec.build(d)?,
            sq_buf: vec![0.0; d],
            d,
        })
    }

    pub fn dim(&self) -> usize {
        self.d
    }

    pub fn t(&self) -> u64 {
        self.mean_avg.t()
    }

    /// Ingest one activation vector.
    pub fn observe(&mut self, x: &[f64]) {
        assert_eq!(x.len(), self.d);
        self.mean_avg.observe(x);
        for (s, &xv) in self.sq_buf.iter_mut().zip(x) {
            *s = xv * xv;
        }
        self.sq_avg.observe(&self.sq_buf);
    }

    /// Current mean estimate per coordinate.
    pub fn mean_into(&self, out: &mut [f64]) -> bool {
        self.mean_avg.value_into(out)
    }

    /// Current variance estimate per coordinate
    /// (`E[x²] − E[x]²`, clamped at 0).
    pub fn variance_into(&self, out: &mut [f64]) -> bool {
        if !self.sq_avg.value_into(out) {
            return false;
        }
        let mut mean = vec![0.0; self.d];
        if !self.mean_avg.value_into(&mut mean) {
            return false;
        }
        for (v, m) in out.iter_mut().zip(&mean) {
            *v = (*v - m * m).max(0.0);
        }
        true
    }

    /// Normalize `x` in place with the current statistics:
    /// `(x − μ)/√(σ² + eps)`. Returns `false` (leaving `x` unchanged)
    /// until statistics exist.
    pub fn normalize(&self, x: &mut [f64], eps: f64) -> bool {
        assert_eq!(x.len(), self.d);
        let mut mean = vec![0.0; self.d];
        let mut var = vec![0.0; self.d];
        if !self.mean_into(&mut mean) || !self.variance_into(&mut var) {
            return false;
        }
        for ((xv, m), v) in x.iter_mut().zip(&mean).zip(&var) {
            *xv = (*xv - m) / (v + eps).sqrt();
        }
        true
    }

    pub fn memory_floats(&self) -> usize {
        self.mean_avg.memory_floats() + self.sq_avg.memory_floats() + self.sq_buf.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{GaussianSource, Xoshiro256};

    #[test]
    fn welford_matches_two_pass() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut s = RunningStats::new();
        for &x in &xs {
            s.push(x);
        }
        assert_eq!(s.count(), 8);
        assert_eq!(s.mean(), 5.0);
        assert_eq!(s.variance(), 4.0);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
    }

    #[test]
    fn merge_equals_single_stream() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64 * 0.77).sin() * 3.0).collect();
        let mut whole = RunningStats::new();
        for &x in &xs {
            whole.push(x);
        }
        let mut a = RunningStats::new();
        let mut b = RunningStats::new();
        for &x in &xs[..37] {
            a.push(x);
        }
        for &x in &xs[37..] {
            b.push(x);
        }
        a.merge(&b);
        assert!((a.mean() - whole.mean()).abs() < 1e-12);
        assert!((a.variance() - whole.variance()).abs() < 1e-12);
        assert_eq!(a.count(), whole.count());
    }

    #[test]
    fn empty_stats_are_nan() {
        let s = RunningStats::new();
        assert!(s.mean().is_nan());
        assert!(s.variance().is_nan());
    }

    #[test]
    fn moment_tracker_estimates_gaussian_moments() {
        let d = 4;
        let spec = AveragerSpec::Gea { c: 0.5 };
        let mut tr = MomentTracker::new(d, &spec).unwrap();
        let mut g = GaussianSource::new(Xoshiro256::seed_from_u64(5));
        let true_means = [0.0, 1.0, -2.0, 5.0];
        let true_stds = [1.0, 0.5, 2.0, 0.1];
        let mut x = vec![0.0; d];
        for _ in 0..20_000 {
            for i in 0..d {
                x[i] = true_means[i] + true_stds[i] * g.next_gaussian();
            }
            tr.observe(&x);
        }
        let mut mean = vec![0.0; d];
        let mut var = vec![0.0; d];
        assert!(tr.mean_into(&mut mean));
        assert!(tr.variance_into(&mut var));
        for i in 0..d {
            assert!(
                (mean[i] - true_means[i]).abs() < 0.1,
                "mean[{i}]={}",
                mean[i]
            );
            let tv = true_stds[i] * true_stds[i];
            assert!(
                (var[i] - tv).abs() < 0.12 * tv.max(0.1),
                "var[{i}]={} want {tv}",
                var[i]
            );
        }
    }

    #[test]
    fn normalize_whitens() {
        let d = 2;
        let spec = AveragerSpec::Gea { c: 0.5 };
        let mut tr = MomentTracker::new(d, &spec).unwrap();
        let mut g = GaussianSource::new(Xoshiro256::seed_from_u64(8));
        let mut x = vec![0.0; d];
        for _ in 0..5000 {
            x[0] = 3.0 + 2.0 * g.next_gaussian();
            x[1] = -1.0 + 0.5 * g.next_gaussian();
            tr.observe(&x);
        }
        // Normalize a fresh stream and check its moments.
        let mut s0 = RunningStats::new();
        let mut s1 = RunningStats::new();
        for _ in 0..5000 {
            x[0] = 3.0 + 2.0 * g.next_gaussian();
            x[1] = -1.0 + 0.5 * g.next_gaussian();
            assert!(tr.normalize(&mut x, 1e-8));
            s0.push(x[0]);
            s1.push(x[1]);
        }
        assert!(s0.mean().abs() < 0.1);
        assert!((s0.variance() - 1.0).abs() < 0.15);
        assert!(s1.mean().abs() < 0.1);
        assert!((s1.variance() - 1.0).abs() < 0.15);
    }

    #[test]
    fn tracker_unavailable_before_data() {
        let tr = MomentTracker::new(3, &AveragerSpec::Gea { c: 0.5 }).unwrap();
        let mut out = vec![0.0; 3];
        assert!(!tr.mean_into(&mut out));
        assert!(!tr.variance_into(&mut out));
        let mut x = vec![1.0; 3];
        assert!(!tr.normalize(&mut x, 1e-8));
        assert_eq!(x, vec![1.0; 3]);
    }

    #[test]
    fn tracker_memory_constant() {
        let spec = AveragerSpec::Awa {
            window: crate::averagers::WindowKind::Growing { c: 0.5 },
            accumulators: 3,
        };
        let mut tr = MomentTracker::new(8, &spec).unwrap();
        let m = tr.memory_floats();
        for _ in 0..2000 {
            tr.observe(&[0.5; 8]);
        }
        assert_eq!(tr.memory_floats(), m);
    }
}
