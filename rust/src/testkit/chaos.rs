//! Deterministic chaos harness: seeded fault injection for the
//! survivability soak tests.
//!
//! The hooks are compiled into the production crate (there is no
//! `cfg(test)` gating — integration tests link the same library the
//! binary does) but cost one relaxed atomic load while disarmed, so
//! they are free on the hot path in normal operation.
//!
//! Determinism: every fault decision is a pure function of
//! `(plan.seed, site, n)` where `n` counts decisions *at that site*.
//! Thread interleaving can reorder which operation hits decision `n`,
//! but the fault schedule per site is identical across runs of the same
//! plan, which is what the soak's invariant assertions need to be
//! replayable from a seed.
//!
//! Fault kinds map onto the failure modes the survivability layer
//! defends against:
//!
//! * [`Site::TornWrite`] — a WAL framed append is truncated mid-record
//!   (recovery must stop cleanly at the tear).
//! * [`Site::FsyncError`] / [`Site::FsyncDelay`] — the durability
//!   syscall fails or stalls (availability-over-durability accounting).
//! * [`Site::ConnReset`] — the server drops a connection mid-stream
//!   (the retrying client must reconnect and re-handshake).
//! * [`Site::WorkerPanic`] — a shard worker dies mid-batch (the
//!   supervisor must quarantine, restart, and keep the rest serving).
//!
//! Clock-skewed deadlines are modelled as a constant skew the server
//! adds to its idle/read deadline arithmetic while armed.

use crate::rng::{RngCore, SplitMix64};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// Seeded fault plan: each probability is per-mille (0..=1000) per
/// decision at that site.
#[derive(Clone, Copy, Debug, Default)]
pub struct ChaosPlan {
    pub seed: u64,
    /// Probability a WAL framed write is torn (partially written).
    pub torn_write_per_mille: u16,
    /// Probability a WAL fsync returns an I/O error.
    pub fsync_error_per_mille: u16,
    /// Probability a WAL fsync stalls for `fsync_delay_micros`.
    pub fsync_delay_per_mille: u16,
    /// Stall applied when an fsync delay fires.
    pub fsync_delay_micros: u64,
    /// Probability the server resets a connection before reading the
    /// next frame.
    pub conn_reset_per_mille: u16,
    /// Probability a shard worker panics before applying a push batch.
    pub panic_per_mille: u16,
    /// Restrict worker-panic injection to streams whose name starts
    /// with this prefix (None = every stream is eligible). Lets tests
    /// sharing a process target their own streams only.
    pub panic_prefix: Option<&'static str>,
    /// Constant skew added to server deadline arithmetic while armed.
    pub clock_skew_ms: u64,
}

/// Fault-injection sites; each has an independent decision stream and
/// an injected-fault counter.
#[derive(Clone, Copy, Debug)]
pub enum Site {
    TornWrite = 0,
    FsyncError = 1,
    FsyncDelay = 2,
    ConnReset = 3,
    WorkerPanic = 4,
}

const SITES: usize = 5;

static ARMED: AtomicBool = AtomicBool::new(false);
static PLAN: Mutex<Option<ChaosPlan>> = Mutex::new(None);
static DECISIONS: [AtomicU64; SITES] = [
    AtomicU64::new(0),
    AtomicU64::new(0),
    AtomicU64::new(0),
    AtomicU64::new(0),
    AtomicU64::new(0),
];
static INJECTED: [AtomicU64; SITES] = [
    AtomicU64::new(0),
    AtomicU64::new(0),
    AtomicU64::new(0),
    AtomicU64::new(0),
    AtomicU64::new(0),
];

/// Install `plan` and arm every hook. Resets decision/injection
/// counters so consecutive soak phases start from a clean schedule.
pub fn arm(plan: ChaosPlan) {
    let mut guard = lock_plan();
    for i in 0..SITES {
        DECISIONS[i].store(0, Ordering::Relaxed);
        INJECTED[i].store(0, Ordering::Relaxed);
    }
    *guard = Some(plan);
    ARMED.store(true, Ordering::Release);
}

/// Disarm all hooks (the plan is dropped; counters keep their totals
/// for post-mortem assertions).
pub fn disarm() {
    ARMED.store(false, Ordering::Release);
    *lock_plan() = None;
}

/// Cheap hot-path guard: is a chaos plan armed?
#[inline]
pub fn armed() -> bool {
    ARMED.load(Ordering::Acquire)
}

/// Faults injected so far at `site` (survives `disarm`).
pub fn injected(site: Site) -> u64 {
    INJECTED[site as usize].load(Ordering::Relaxed)
}

fn lock_plan() -> std::sync::MutexGuard<'static, Option<ChaosPlan>> {
    // The chaos harness must keep working after a test thread panicked
    // while holding the lock (that is the whole point of the exercise).
    PLAN.lock().unwrap_or_else(|e| e.into_inner())
}

/// Draw decision `n` for `site`: a raw u64 that is a pure function of
/// `(seed, site, n)`. Returns `None` while disarmed.
fn draw(site: Site) -> Option<(ChaosPlan, u64)> {
    if !armed() {
        return None;
    }
    let plan = (*lock_plan())?;
    let n = DECISIONS[site as usize].fetch_add(1, Ordering::Relaxed);
    let raw = SplitMix64::new(plan.seed)
        .split(site as u64)
        .split(n)
        .next_u64();
    Some((plan, raw))
}

fn fire(site: Site, per_mille: u16, raw: u64) -> bool {
    if raw % 1000 < per_mille as u64 {
        INJECTED[site as usize].fetch_add(1, Ordering::Relaxed);
        true
    } else {
        false
    }
}

/// WAL hook: should this framed append of `len` bytes be torn?
/// Returns how many bytes to actually write (strictly less than `len`)
/// before reporting an I/O error, simulating a crash mid-write.
pub fn torn_write(len: usize) -> Option<usize> {
    let (plan, raw) = draw(Site::TornWrite)?;
    if len == 0 || !fire(Site::TornWrite, plan.torn_write_per_mille, raw) {
        return None;
    }
    Some((raw >> 16) as usize % len)
}

/// WAL hook: fault the next fsync? `Some(err)` simulates the syscall
/// failing; a delay-only fault sleeps here and returns `None`.
pub fn fsync_fault() -> Option<std::io::Error> {
    if let Some((plan, raw)) = draw(Site::FsyncDelay) {
        if plan.fsync_delay_micros > 0 && fire(Site::FsyncDelay, plan.fsync_delay_per_mille, raw) {
            std::thread::sleep(Duration::from_micros(plan.fsync_delay_micros));
        }
    }
    let (plan, raw) = draw(Site::FsyncError)?;
    if fire(Site::FsyncError, plan.fsync_error_per_mille, raw) {
        return Some(std::io::Error::other("chaos: injected fsync failure"));
    }
    None
}

/// Server hook: reset this connection before reading the next frame?
pub fn conn_reset() -> bool {
    match draw(Site::ConnReset) {
        Some((plan, raw)) => fire(Site::ConnReset, plan.conn_reset_per_mille, raw),
        None => false,
    }
}

/// Shard-loop hook: panic *before* the batch for `stream` reaches the
/// WAL or the estimator. Injecting ahead of any mutation keeps live
/// state and the recovery replay bitwise-identical — the quarantined
/// batch simply never happened on either side.
pub fn maybe_worker_panic(stream: &str) {
    if !armed() {
        return;
    }
    // Eligibility check before drawing, so a prefix filter does not
    // consume decisions for streams it never targets.
    match *lock_plan() {
        Some(plan) => {
            if let Some(prefix) = plan.panic_prefix {
                if !stream.starts_with(prefix) {
                    return;
                }
            }
        }
        None => return,
    }
    if let Some((plan, raw)) = draw(Site::WorkerPanic) {
        if fire(Site::WorkerPanic, plan.panic_per_mille, raw) {
            panic!("chaos: injected worker panic on stream '{stream}'");
        }
    }
}

/// Serializes tests that arm the (process-global) harness. Any test —
/// in this module or elsewhere in the crate — that calls [`arm`] must
/// hold this lock for its duration, or a concurrent `arm`/`disarm`
/// would rewrite its fault schedule mid-flight.
pub fn test_mutex() -> &'static Mutex<()> {
    static M: Mutex<()> = Mutex::new(());
    &M
}

/// Constant deadline skew the server applies while armed (models a
/// wall-clock jump shrinking every in-flight deadline).
pub fn clock_skew() -> Duration {
    if !armed() {
        return Duration::ZERO;
    }
    match *lock_plan() {
        Some(plan) => Duration::from_millis(plan.clock_skew_ms),
        None => Duration::ZERO,
    }
}

#[cfg(test)]
mod tests {
    use super::*;


    #[test]
    fn disarmed_hooks_are_inert() {
        let _g = test_mutex().lock().unwrap_or_else(|e| e.into_inner());
        disarm();
        assert!(torn_write(128).is_none());
        assert!(fsync_fault().is_none());
        assert!(!conn_reset());
        maybe_worker_panic("s"); // must not panic
        assert_eq!(clock_skew(), Duration::ZERO);
    }

    #[test]
    fn decisions_are_deterministic_per_site() {
        let _g = test_mutex().lock().unwrap_or_else(|e| e.into_inner());
        let plan = ChaosPlan {
            seed: 0xC4A05,
            torn_write_per_mille: 500,
            ..Default::default()
        };
        arm(plan);
        let a: Vec<Option<usize>> = (0..64).map(|_| torn_write(100)).collect();
        arm(plan); // re-arm resets the decision counters
        let b: Vec<Option<usize>> = (0..64).map(|_| torn_write(100)).collect();
        disarm();
        assert_eq!(a, b);
        assert!(a.iter().any(Option::is_some), "p=0.5 over 64 draws");
        assert!(a.iter().any(Option::is_none));
        // Tears are strictly shorter than the record.
        for t in a.into_iter().flatten() {
            assert!(t < 100);
        }
    }

    #[test]
    fn injection_counters_track_fires() {
        let _g = test_mutex().lock().unwrap_or_else(|e| e.into_inner());
        arm(ChaosPlan {
            seed: 7,
            conn_reset_per_mille: 1000,
            clock_skew_ms: 250,
            ..Default::default()
        });
        assert!(conn_reset());
        assert!(conn_reset());
        assert_eq!(injected(Site::ConnReset), 2);
        assert_eq!(clock_skew(), Duration::from_millis(250));
        disarm();
        assert_eq!(injected(Site::ConnReset), 2);
    }
}
