//! Property-based testing mini-framework (the `proptest` substitute).
//!
//! A property test here is: a seeded generator producing random *cases*, a
//! predicate over cases, and a runner that executes many cases, reports the
//! first failing case with its seed (so it can be replayed), and attempts a
//! simple shrink by re-running the failing generator with smaller size
//! hints.
//!
//! ```
//! use ata::testkit::{Gen, Runner};
//!
//! let mut runner = Runner::new("addition commutes", 0xA7A);
//! runner.run(200, |g| {
//!     let a = g.f64_range(-1e6, 1e6);
//!     let b = g.f64_range(-1e6, 1e6);
//!     ((a + b) - (b + a)).abs() < 1e-12
//! });
//! ```

pub mod chaos;

use crate::rng::{RngCore, SplitMix64, Xoshiro256};

/// Random case generator handed to property bodies.
pub struct Gen {
    rng: Xoshiro256,
    /// Size hint in `[0, 1]`: shrinking reruns with smaller sizes.
    pub size: f64,
}

impl Gen {
    fn new(seed: u64, case: u64, size: f64) -> Gen {
        Gen {
            rng: Xoshiro256::substream(seed, case),
            size,
        }
    }

    /// Uniform u64.
    pub fn u64(&mut self) -> u64 {
        self.rng.next_u64()
    }

    /// Uniform usize in `[lo, hi]` (inclusive), scaled down when shrinking.
    pub fn usize_range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        let span = (hi - lo) as f64;
        let scaled_hi = lo + (span * self.size).round() as usize;
        let scaled_hi = scaled_hi.max(lo);
        lo + self.rng.next_below((scaled_hi - lo + 1) as u64) as usize
    }

    /// Uniform f64 in `[lo, hi)`.
    pub fn f64_range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.rng.next_f64()
    }

    /// Uniform f64 in `[lo, hi)` with magnitude scaled by current size.
    pub fn f64_sized(&mut self, lo: f64, hi: f64) -> f64 {
        let mid = 0.5 * (lo + hi);
        let half = 0.5 * (hi - lo) * self.size;
        self.f64_range(mid - half, mid + half)
    }

    /// Bernoulli(p).
    pub fn bool(&mut self, p: f64) -> bool {
        self.rng.next_f64() < p
    }

    /// Pick a uniformly random element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        assert!(!xs.is_empty());
        &xs[self.rng.next_below(xs.len() as u64) as usize]
    }

    /// A vector of f64 drawn from `[lo, hi)`, length in `[min_len, max_len]`.
    pub fn f64_vec(&mut self, min_len: usize, max_len: usize, lo: f64, hi: f64) -> Vec<f64> {
        let n = self.usize_range(min_len, max_len);
        (0..n).map(|_| self.f64_range(lo, hi)).collect()
    }

    /// Standard-ish normal deviate (sum of uniforms — adequate for tests).
    pub fn gaussian(&mut self) -> f64 {
        // Irwin–Hall with 12 uniforms: mean 6, var 1.
        let s: f64 = (0..12).map(|_| self.rng.next_f64()).sum();
        s - 6.0
    }
}

/// Outcome of a property body. `bool` works for simple predicates;
/// `Result<(), String>` carries a failure message.
pub trait Outcome {
    fn failure(self) -> Option<String>;
}

impl Outcome for bool {
    fn failure(self) -> Option<String> {
        if self {
            None
        } else {
            Some("property returned false".to_string())
        }
    }
}

impl Outcome for Result<(), String> {
    fn failure(self) -> Option<String> {
        self.err()
    }
}

/// Property-test runner. Panics (test failure) on the first falsified case,
/// printing the property name, case index, seed and shrink trace.
pub struct Runner {
    name: &'static str,
    seed: u64,
}

impl Runner {
    /// `seed` makes the whole run reproducible; derive per-case seeds
    /// internally.
    pub fn new(name: &'static str, seed: u64) -> Runner {
        // Mix the name into the seed so distinct properties with the same
        // literal seed do not see identical streams.
        let mut h = SplitMix64::new(seed ^ 0x5EED);
        let mut acc = h.next_u64();
        for b in name.bytes() {
            acc = acc.rotate_left(7) ^ (b as u64);
        }
        Runner { name, seed: acc }
    }

    /// Run `cases` random cases of the property `body`.
    pub fn run<O: Outcome>(&mut self, cases: u64, mut body: impl FnMut(&mut Gen) -> O) {
        for case in 0..cases {
            let mut g = Gen::new(self.seed, case, 1.0);
            if let Some(msg) = body(&mut g).failure() {
                // Attempt shrink: rerun the same case stream at smaller
                // sizes; report the smallest size that still fails.
                let mut smallest = 1.0f64;
                for &size in &[0.5, 0.25, 0.1, 0.05, 0.01] {
                    let mut gs = Gen::new(self.seed, case, size);
                    if body(&mut gs).failure().is_some() {
                        smallest = size;
                    }
                }
                panic!(
                    "property '{}' falsified at case {case} (seed {:#x}, \
                     smallest failing size {smallest}): {msg}",
                    self.name, self.seed
                );
            }
        }
    }
}

/// Create a fresh, unique temporary directory for a test. Callers that
/// care about disk hygiene can `std::fs::remove_dir_all` it at the end;
/// leaking it on test failure is deliberate (the artifacts help debug).
pub fn temp_dir(label: &str) -> std::path::PathBuf {
    use std::sync::atomic::{AtomicU64, Ordering};
    static NEXT: AtomicU64 = AtomicU64::new(0);
    let n = NEXT.fetch_add(1, Ordering::Relaxed);
    let nanos = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_nanos() as u64)
        .unwrap_or(0);
    let dir = std::env::temp_dir().join(format!(
        "ata-test-{label}-{}-{n}-{nanos}",
        std::process::id()
    ));
    std::fs::create_dir_all(&dir).expect("create temp dir");
    dir
}

/// Assert two floats are close (absolute + relative tolerance).
pub fn assert_close(got: f64, want: f64, tol: f64, ctx: &str) -> Result<(), String> {
    let scale = want.abs().max(1.0);
    if (got - want).abs() <= tol * scale {
        Ok(())
    } else {
        Err(format!("{ctx}: got {got}, want {want} (tol {tol})"))
    }
}

/// Assert two slices are elementwise close.
pub fn assert_slice_close(got: &[f64], want: &[f64], tol: f64, ctx: &str) -> Result<(), String> {
    if got.len() != want.len() {
        return Err(format!(
            "{ctx}: length mismatch {} vs {}",
            got.len(),
            want.len()
        ));
    }
    for (i, (&g, &w)) in got.iter().zip(want).enumerate() {
        assert_close(g, w, tol, &format!("{ctx}[{i}]"))?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_completes() {
        Runner::new("abs is nonneg", 1).run(500, |g| g.f64_range(-10.0, 10.0).abs() >= 0.0);
    }

    #[test]
    #[should_panic(expected = "falsified")]
    fn failing_property_panics_with_context() {
        Runner::new("all values below 0.5", 2).run(500, |g| g.f64_range(0.0, 1.0) < 0.5);
    }

    #[test]
    fn generators_are_deterministic_per_seed() {
        let mut a = Gen::new(42, 0, 1.0);
        let mut b = Gen::new(42, 0, 1.0);
        for _ in 0..32 {
            assert_eq!(a.u64(), b.u64());
        }
    }

    #[test]
    fn usize_range_respects_bounds() {
        let mut g = Gen::new(9, 3, 1.0);
        for _ in 0..1000 {
            let v = g.usize_range(3, 17);
            assert!((3..=17).contains(&v));
        }
    }

    #[test]
    fn shrunk_sizes_reduce_ranges() {
        let mut g = Gen::new(10, 0, 0.01);
        for _ in 0..100 {
            // With size 0.01 over [0, 1000], values stay tiny.
            assert!(g.usize_range(0, 1000) <= 10);
        }
    }

    #[test]
    fn result_outcome_carries_message() {
        let r: Result<(), String> = Err("boom".to_string());
        assert_eq!(r.failure(), Some("boom".to_string()));
        assert_eq!(Ok::<(), String>(()).failure(), None);
    }

    #[test]
    fn close_helpers() {
        assert!(assert_close(1.0, 1.0 + 1e-12, 1e-9, "x").is_ok());
        assert!(assert_close(1.0, 2.0, 1e-9, "x").is_err());
        assert!(assert_slice_close(&[1.0, 2.0], &[1.0, 2.0], 1e-12, "v").is_ok());
        assert!(assert_slice_close(&[1.0], &[1.0, 2.0], 1e-12, "v").is_err());
    }
}
