//! Declarative command-line parsing (the launcher's `clap` substitute).
//!
//! Supports subcommands, `--flag`, `--key value` / `--key=value` options,
//! typed accessors with defaults, required options, positional arguments and
//! auto-generated `--help` text.

use std::collections::BTreeMap;
use std::fmt;

/// Specification of one option/flag.
#[derive(Clone, Debug)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    /// `true` for boolean flags (no value), `false` for `--key value`.
    pub is_flag: bool,
    pub default: Option<&'static str>,
    pub required: bool,
}

/// Specification of a (sub)command: its options and positionals.
#[derive(Clone, Debug, Default)]
pub struct CommandSpec {
    pub name: &'static str,
    pub about: &'static str,
    pub opts: Vec<OptSpec>,
    pub positionals: Vec<(&'static str, &'static str)>,
}

impl CommandSpec {
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Self {
            name,
            about,
            ..Default::default()
        }
    }

    /// Add a boolean flag.
    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec {
            name,
            help,
            is_flag: true,
            default: None,
            required: false,
        });
        self
    }

    /// Add a valued option with a default.
    pub fn opt(mut self, name: &'static str, default: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec {
            name,
            help,
            is_flag: false,
            default: Some(default),
            required: false,
        });
        self
    }

    /// Add a required valued option.
    pub fn req(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec {
            name,
            help,
            is_flag: false,
            default: None,
            required: true,
        });
        self
    }

    /// Add a positional argument.
    pub fn positional(mut self, name: &'static str, help: &'static str) -> Self {
        self.positionals.push((name, help));
        self
    }

    /// Render help text.
    pub fn help_text(&self, program: &str) -> String {
        let mut s = String::new();
        s.push_str(&format!("{}\n\nUsage: {program} {}", self.about, self.name));
        for (p, _) in &self.positionals {
            s.push_str(&format!(" <{p}>"));
        }
        s.push_str(" [options]\n");
        if !self.positionals.is_empty() {
            s.push_str("\nArguments:\n");
            for (p, h) in &self.positionals {
                s.push_str(&format!("  <{p}>  {h}\n"));
            }
        }
        if !self.opts.is_empty() {
            s.push_str("\nOptions:\n");
            for o in &self.opts {
                let lhs = if o.is_flag {
                    format!("--{}", o.name)
                } else if let Some(d) = o.default {
                    format!("--{} <v> (default {d})", o.name)
                } else {
                    format!("--{} <v> (required)", o.name)
                };
                s.push_str(&format!("  {lhs:<34} {}\n", o.help));
            }
        }
        s
    }

    /// Parse `args` (not including the program/command names) against this
    /// spec.
    pub fn parse(&self, args: &[String]) -> Result<Parsed, CliError> {
        let mut values: BTreeMap<String, String> = BTreeMap::new();
        let mut flags: Vec<String> = Vec::new();
        let mut positionals: Vec<String> = Vec::new();
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            if a == "--help" || a == "-h" {
                return Err(CliError::HelpRequested);
            }
            if let Some(stripped) = a.strip_prefix("--") {
                let (key, inline_val) = match stripped.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let spec = self
                    .opts
                    .iter()
                    .find(|o| o.name == key)
                    .ok_or_else(|| CliError::UnknownOption(key.clone()))?;
                if spec.is_flag {
                    if inline_val.is_some() {
                        return Err(CliError::Malformed(format!(
                            "flag --{key} does not take a value"
                        )));
                    }
                    flags.push(key);
                } else {
                    let val = match inline_val {
                        Some(v) => v,
                        None => {
                            i += 1;
                            args.get(i)
                                .cloned()
                                .ok_or_else(|| CliError::MissingValue(key.clone()))?
                        }
                    };
                    values.insert(key, val);
                }
            } else {
                positionals.push(a.clone());
            }
            i += 1;
        }
        if positionals.len() > self.positionals.len() {
            return Err(CliError::Malformed(format!(
                "unexpected positional argument '{}'",
                positionals[self.positionals.len()]
            )));
        }
        for o in &self.opts {
            if o.required && !values.contains_key(o.name) {
                return Err(CliError::MissingRequired(o.name.to_string()));
            }
            if let Some(d) = o.default {
                values.entry(o.name.to_string()).or_insert_with(|| d.to_string());
            }
        }
        Ok(Parsed {
            values,
            flags,
            positionals,
        })
    }
}

/// Parsed arguments with typed accessors.
#[derive(Debug, Clone)]
pub struct Parsed {
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    positionals: Vec<String>,
}

impl Parsed {
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(String::as_str)
    }

    pub fn str(&self, name: &str) -> String {
        self.get(name).unwrap_or_default().to_string()
    }

    pub fn positional(&self, idx: usize) -> Option<&str> {
        self.positionals.get(idx).map(String::as_str)
    }

    pub fn u64(&self, name: &str) -> Result<u64, CliError> {
        self.typed(name, |s| s.parse::<u64>().ok())
    }

    pub fn usize(&self, name: &str) -> Result<usize, CliError> {
        self.typed(name, |s| s.parse::<usize>().ok())
    }

    pub fn f64(&self, name: &str) -> Result<f64, CliError> {
        self.typed(name, |s| s.parse::<f64>().ok())
    }

    fn typed<T>(&self, name: &str, conv: impl Fn(&str) -> Option<T>) -> Result<T, CliError> {
        let raw = self
            .get(name)
            .ok_or_else(|| CliError::MissingRequired(name.to_string()))?;
        conv(raw).ok_or_else(|| CliError::Malformed(format!("--{name}: cannot parse '{raw}'")))
    }
}

/// CLI parse failures.
#[derive(Debug, Clone, PartialEq)]
pub enum CliError {
    HelpRequested,
    UnknownOption(String),
    MissingValue(String),
    MissingRequired(String),
    Malformed(String),
}

impl fmt::Display for CliError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CliError::HelpRequested => write!(f, "help requested"),
            CliError::UnknownOption(o) => write!(f, "unknown option --{o}"),
            CliError::MissingValue(o) => write!(f, "option --{o} requires a value"),
            CliError::MissingRequired(o) => write!(f, "missing required option --{o}"),
            CliError::Malformed(m) => write!(f, "{m}"),
        }
    }
}

impl std::error::Error for CliError {}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> CommandSpec {
        CommandSpec::new("run", "run an experiment")
            .opt("steps", "1000", "number of steps")
            .opt("c", "0.5", "window fraction")
            .flag("verbose", "chatty output")
            .req("out", "output path")
            .positional("config", "config file")
    }

    fn args(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_and_overrides() {
        let p = spec()
            .parse(&args(&["--steps", "50", "--out=/tmp/x", "cfg.toml"]))
            .unwrap();
        assert_eq!(p.u64("steps").unwrap(), 50);
        assert_eq!(p.f64("c").unwrap(), 0.5);
        assert_eq!(p.str("out"), "/tmp/x");
        assert_eq!(p.positional(0), Some("cfg.toml"));
        assert!(!p.flag("verbose"));
    }

    #[test]
    fn flags_parse() {
        let p = spec()
            .parse(&args(&["--verbose", "--out", "o"]))
            .unwrap();
        assert!(p.flag("verbose"));
    }

    #[test]
    fn missing_required_rejected() {
        let e = spec().parse(&args(&["--steps", "5"])).unwrap_err();
        assert_eq!(e, CliError::MissingRequired("out".to_string()));
    }

    #[test]
    fn unknown_option_rejected() {
        let e = spec().parse(&args(&["--bogus", "--out", "o"])).unwrap_err();
        assert_eq!(e, CliError::UnknownOption("bogus".to_string()));
    }

    #[test]
    fn missing_value_rejected() {
        let e = spec().parse(&args(&["--out"])).unwrap_err();
        assert_eq!(e, CliError::MissingValue("out".to_string()));
    }

    #[test]
    fn flag_with_value_rejected() {
        let e = spec()
            .parse(&args(&["--verbose=yes", "--out", "o"]))
            .unwrap_err();
        assert!(matches!(e, CliError::Malformed(_)));
    }

    #[test]
    fn help_flag_surfaces() {
        let e = spec().parse(&args(&["--help"])).unwrap_err();
        assert_eq!(e, CliError::HelpRequested);
        assert!(spec().help_text("ata").contains("--steps"));
    }

    #[test]
    fn bad_typed_value_rejected() {
        let p = spec()
            .parse(&args(&["--steps", "abc", "--out", "o"]))
            .unwrap();
        assert!(p.u64("steps").is_err());
    }

    #[test]
    fn excess_positionals_rejected() {
        let e = spec()
            .parse(&args(&["--out", "o", "a.toml", "b.toml"]))
            .unwrap_err();
        assert!(matches!(e, CliError::Malformed(_)));
    }
}
