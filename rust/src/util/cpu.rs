//! CPU topology helpers for the coordinator's hot path: cache-line
//! padding to kill false sharing, and opt-in worker→core pinning.
//!
//! The offline registry ships no `libc`, so pinning talks to the kernel
//! directly through the `sched_setaffinity` syscall on Linux
//! x86_64/aarch64 and degrades to a graceful no-op everywhere else
//! (macOS has no public affinity API; other targets simply skip it).
//! Pinning is best-effort by design: a `false` return means the shard
//! keeps running unpinned, never that it fails.

/// Pads (and aligns) `T` to a 64-byte cache line so two instances can
/// never share a line — the fix for false sharing between per-shard
/// counters that are written from different worker threads. `Deref`
/// keeps call sites transparent.
#[derive(Default, Debug)]
#[repr(align(64))]
pub struct CachePadded<T>(T);

impl<T> CachePadded<T> {
    pub const fn new(value: T) -> CachePadded<T> {
        CachePadded(value)
    }

    /// Consume the padding, returning the inner value.
    pub fn into_inner(self) -> T {
        self.0
    }
}

impl<T> std::ops::Deref for CachePadded<T> {
    type Target = T;
    #[inline]
    fn deref(&self) -> &T {
        &self.0
    }
}

impl<T> std::ops::DerefMut for CachePadded<T> {
    #[inline]
    fn deref_mut(&mut self) -> &mut T {
        &mut self.0
    }
}

/// CPU-set capacity of the raw affinity mask (bits). Matches the
/// kernel's default `CONFIG_NR_CPUS` ceiling on the targets we pin.
const MASK_BITS: usize = 1024;

/// Pin the calling thread to `core` (a logical CPU index). Returns
/// `true` when the kernel accepted the mask; `false` on unsupported
/// targets, out-of-range cores, or kernel refusal — callers treat a
/// `false` as "run unpinned", never as an error.
pub fn pin_current_thread(core: usize) -> bool {
    if core >= MASK_BITS {
        return false;
    }
    pin_impl(core)
}

/// Number of logical CPUs (for choosing pin targets); 1 when unknown.
pub fn logical_cpus() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

#[cfg(all(target_os = "linux", target_arch = "x86_64"))]
fn pin_impl(core: usize) -> bool {
    let mut mask = [0u64; MASK_BITS / 64];
    mask[core / 64] = 1u64 << (core % 64);
    // sched_setaffinity(pid = 0 /* self */, len, mask) — syscall 203.
    let ret: isize;
    unsafe {
        core::arch::asm!(
            "syscall",
            inlateout("rax") 203isize => ret,
            in("rdi") 0usize,
            in("rsi") std::mem::size_of_val(&mask),
            in("rdx") mask.as_ptr(),
            lateout("rcx") _,
            lateout("r11") _,
            options(nostack, readonly)
        );
    }
    ret == 0
}

#[cfg(all(target_os = "linux", target_arch = "aarch64"))]
fn pin_impl(core: usize) -> bool {
    let mut mask = [0u64; MASK_BITS / 64];
    mask[core / 64] = 1u64 << (core % 64);
    // sched_setaffinity(pid = 0 /* self */, len, mask) — syscall 122.
    let ret: isize;
    unsafe {
        core::arch::asm!(
            "svc 0",
            in("x8") 122isize,
            inlateout("x0") 0isize => ret,
            in("x1") std::mem::size_of_val(&mask),
            in("x2") mask.as_ptr(),
            options(nostack, readonly)
        );
    }
    ret == 0
}

#[cfg(not(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64"))))]
fn pin_impl(_core: usize) -> bool {
    false
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn cache_padded_is_line_sized_and_transparent() {
        assert_eq!(std::mem::align_of::<CachePadded<AtomicU64>>(), 64);
        assert!(std::mem::size_of::<CachePadded<AtomicU64>>() >= 64);
        let c = CachePadded::new(7u64);
        assert_eq!(*c, 7);
        let mut m = CachePadded::new(vec![1]);
        m.push(2);
        assert_eq!(m.into_inner(), vec![1, 2]);
    }

    #[test]
    fn pinning_is_best_effort() {
        // On Linux this genuinely pins to core 0 (always present); on
        // other targets it must return false without side effects.
        let ok = pin_current_thread(0);
        if cfg!(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64"))) {
            assert!(ok, "pinning to core 0 should succeed on linux");
        } else {
            assert!(!ok);
        }
        // Out-of-range cores are rejected locally, never passed down.
        assert!(!pin_current_thread(usize::MAX));
        assert!(logical_cpus() >= 1);
    }
}
