//! Human-readable formatting for reports and benches.

use std::time::Duration;

/// Format a duration adaptively: `ns`, `µs`, `ms` or `s`.
pub fn duration(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns}ns")
    } else if ns < 1_000_000 {
        format!("{:.2}µs", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2}ms", ns as f64 / 1e6)
    } else {
        format!("{:.2}s", ns as f64 / 1e9)
    }
}

/// Format a byte count adaptively (binary units).
pub fn bytes(n: usize) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = n as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{n}B")
    } else {
        format!("{v:.1}{}", UNITS[u])
    }
}

/// Format a rate (events/sec) adaptively.
pub fn rate(per_sec: f64) -> String {
    if per_sec >= 1e9 {
        format!("{:.2}G/s", per_sec / 1e9)
    } else if per_sec >= 1e6 {
        format!("{:.2}M/s", per_sec / 1e6)
    } else if per_sec >= 1e3 {
        format!("{:.2}k/s", per_sec / 1e3)
    } else {
        format!("{per_sec:.1}/s")
    }
}

/// Scientific-ish compact float for tables: 4 significant digits.
pub fn sig4(x: f64) -> String {
    if x == 0.0 {
        return "0".to_string();
    }
    let a = x.abs();
    if (1e-3..1e5).contains(&a) {
        format!("{x:.4}")
    } else {
        format!("{x:.3e}")
    }
}

/// Left-pad / right-align a string to `w` columns.
pub fn pad_left(s: &str, w: usize) -> String {
    if s.len() >= w {
        s.to_string()
    } else {
        format!("{}{}", " ".repeat(w - s.len()), s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn durations() {
        assert_eq!(duration(Duration::from_nanos(500)), "500ns");
        assert_eq!(duration(Duration::from_micros(12)), "12.00µs");
        assert_eq!(duration(Duration::from_millis(3)), "3.00ms");
        assert_eq!(duration(Duration::from_secs(2)), "2.00s");
    }

    #[test]
    fn byte_counts() {
        assert_eq!(bytes(512), "512B");
        assert_eq!(bytes(2048), "2.0KiB");
        assert_eq!(bytes(3 * 1024 * 1024), "3.0MiB");
    }

    #[test]
    fn rates() {
        assert_eq!(rate(500.0), "500.0/s");
        assert_eq!(rate(2_500_000.0), "2.50M/s");
    }

    #[test]
    fn sig4_ranges() {
        assert_eq!(sig4(0.0), "0");
        assert_eq!(sig4(1.23456), "1.2346");
        assert!(sig4(1.0e-9).contains('e'));
        assert!(sig4(3.2e7).contains('e'));
    }

    #[test]
    fn padding() {
        assert_eq!(pad_left("ab", 5), "   ab");
        assert_eq!(pad_left("abcdef", 3), "abcdef");
    }
}
