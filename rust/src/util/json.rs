//! JSON: value model, recursive-descent parser, compact/pretty encoder.
//!
//! Used for the artifact manifest (`artifacts/manifest.json`), metrics
//! export, golden cross-language test vectors, config interop and the
//! coordinator wire protocol. Implements RFC 8259 minus the exotica we do
//! not produce: `\uXXXX` escapes are parsed (including surrogate pairs) but
//! the encoder emits UTF-8 directly.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are sorted (BTreeMap) so encoding is
/// deterministic — important for golden files and reproducible manifests.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Build an object from key/value pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Build an array of numbers.
    pub fn nums(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 && n <= u64::MAX as f64 {
                Some(n as u64)
            } else {
                None
            }
        })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// `f64` array extraction.
    pub fn to_f64_vec(&self) -> Option<Vec<f64>> {
        self.as_arr()
            .map(|a| a.iter().filter_map(Json::as_f64).collect::<Vec<_>>())
            .filter(|v| Some(v.len()) == self.as_arr().map(<[Json]>::len))
    }

    /// Compact encoding (no whitespace).
    pub fn encode(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Pretty encoding with 2-space indentation.
    pub fn encode_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out.push('\n');
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => out.push_str(&encode_number(*n)),
            Json::Str(s) => encode_string(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                if !a.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    encode_string(k, out);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !o.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }

    /// Parse a JSON document. Trailing whitespace is allowed; trailing
    /// non-whitespace is an error.
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters after document"));
        }
        Ok(v)
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

/// Encode an f64 as a JSON number. Uses shortest round-trip formatting;
/// non-finite values (not representable in JSON) become `null`.
fn encode_number(n: f64) -> String {
    if !n.is_finite() {
        return "null".to_string();
    }
    if n == n.trunc() && n.abs() < 1e15 {
        format!("{}", n as i64)
    } else {
        // `{:?}` on f64 is Rust's shortest round-trip representation.
        let s = format!("{n:?}");
        s
    }
}

fn encode_string(s: &str, out: &mut String) {
    out.push('"');
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse error with byte offset context.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub msg: String,
    pub offset: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            msg: msg.to_string(),
            offset: self.pos,
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(_) => Err(self.err("unexpected character")),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => {
                            s.push('"');
                            self.pos += 1;
                        }
                        Some(b'\\') => {
                            s.push('\\');
                            self.pos += 1;
                        }
                        Some(b'/') => {
                            s.push('/');
                            self.pos += 1;
                        }
                        Some(b'n') => {
                            s.push('\n');
                            self.pos += 1;
                        }
                        Some(b't') => {
                            s.push('\t');
                            self.pos += 1;
                        }
                        Some(b'r') => {
                            s.push('\r');
                            self.pos += 1;
                        }
                        Some(b'b') => {
                            s.push('\u{0008}');
                            self.pos += 1;
                        }
                        Some(b'f') => {
                            s.push('\u{000C}');
                            self.pos += 1;
                        }
                        Some(b'u') => {
                            self.pos += 1;
                            let cp = self.hex4()?;
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                // High surrogate: require a low surrogate.
                                if self.peek() == Some(b'\\') {
                                    self.pos += 1;
                                    self.expect(b'u')?;
                                    let lo = self.hex4()?;
                                    if !(0xDC00..0xE000).contains(&lo) {
                                        return Err(self.err("invalid low surrogate"));
                                    }
                                    let c =
                                        0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                    char::from_u32(c)
                                        .ok_or_else(|| self.err("invalid codepoint"))?
                                } else {
                                    return Err(self.err("lone high surrogate"));
                                }
                            } else if (0xDC00..0xE000).contains(&cp) {
                                return Err(self.err("lone low surrogate"));
                            } else {
                                char::from_u32(cp)
                                    .ok_or_else(|| self.err("invalid codepoint"))?
                            };
                            s.push(ch);
                        }
                        _ => return Err(self.err("invalid escape")),
                    }
                }
                Some(_) => {
                    // Consume one UTF-8 scalar. Find its byte length.
                    let start = self.pos;
                    let b0 = self.bytes[start];
                    let len = match b0 {
                        0x00..=0x7F => 1,
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        0xF0..=0xF7 => 4,
                        _ => return Err(self.err("invalid utf-8")),
                    };
                    if start + len > self.bytes.len() {
                        return Err(self.err("truncated utf-8"));
                    }
                    let chunk = std::str::from_utf8(&self.bytes[start..start + len])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    s.push_str(chunk);
                    self.pos += len;
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        if self.pos + 4 > self.bytes.len() {
            return Err(self.err("truncated \\u escape"));
        }
        let hex = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
            .map_err(|_| self.err("bad \\u escape"))?;
        let v = u32::from_str_radix(hex, 16).map_err(|_| self.err("bad \\u escape"))?;
        self.pos += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("bad number"))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for text in ["null", "true", "false", "0", "-1", "3.25", "1e3"] {
            let v = Json::parse(text).unwrap();
            let re = Json::parse(&v.encode()).unwrap();
            assert_eq!(v, re, "text={text}");
        }
    }

    #[test]
    fn roundtrip_nested() {
        let text = r#"{"a":[1,2,{"b":"x\ny","c":null}],"d":true,"e":-2.5e-3}"#;
        let v = Json::parse(text).unwrap();
        let re = Json::parse(&v.encode()).unwrap();
        assert_eq!(v, re);
        assert_eq!(v.get("d").and_then(Json::as_bool), Some(true));
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2]
                .get("b")
                .and_then(Json::as_str),
            Some("x\ny")
        );
    }

    #[test]
    fn unicode_escapes() {
        let v = Json::parse(r#""é😀""#).unwrap();
        assert_eq!(v, Json::Str("é😀".to_string()));
        // Encoder writes UTF-8 directly and re-parses.
        let enc = Json::Str("é😀".to_string()).encode();
        assert_eq!(Json::parse(&enc).unwrap(), Json::Str("é😀".to_string()));
    }

    #[test]
    fn rejects_garbage() {
        for bad in ["", "{", "[1,]", "{\"a\":}", "tru", "1.2.3", "\"\\q\"", "[1] x"] {
            assert!(Json::parse(bad).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn integers_encode_without_fraction() {
        assert_eq!(Json::Num(5.0).encode(), "5");
        assert_eq!(Json::Num(-3.0).encode(), "-3");
        assert_eq!(Json::Num(0.5).encode(), "0.5");
    }

    #[test]
    fn nonfinite_encodes_as_null() {
        assert_eq!(Json::Num(f64::NAN).encode(), "null");
        assert_eq!(Json::Num(f64::INFINITY).encode(), "null");
    }

    #[test]
    fn f64_roundtrip_precision() {
        let vals = [1.0 / 3.0, 1e-300, 123456789.123456, f64::MIN_POSITIVE];
        for &x in &vals {
            let enc = Json::Num(x).encode();
            let back = Json::parse(&enc).unwrap().as_f64().unwrap();
            assert_eq!(x, back, "value {x} encoded as {enc}");
        }
    }

    #[test]
    fn pretty_encoding_parses_back() {
        let v = Json::obj(vec![
            ("xs", Json::nums(&[1.0, 2.0, 3.5])),
            ("name", Json::Str("run".into())),
        ]);
        let pretty = v.encode_pretty();
        assert!(pretty.contains("\n"));
        assert_eq!(Json::parse(&pretty).unwrap(), v);
    }

    #[test]
    fn object_keys_sorted_deterministically() {
        let v = Json::obj(vec![("z", Json::Num(1.0)), ("a", Json::Num(2.0))]);
        assert_eq!(v.encode(), r#"{"a":2,"z":1}"#);
    }

    #[test]
    fn get_and_as_u64() {
        let v = Json::parse(r#"{"n": 42}"#).unwrap();
        assert_eq!(v.get("n").and_then(Json::as_u64), Some(42));
        let neg = Json::parse(r#"{"n": -1}"#).unwrap();
        assert_eq!(neg.get("n").and_then(Json::as_u64), None);
    }
}
