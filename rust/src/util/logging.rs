//! Leveled, timestamped logging (the `log`/`env_logger` substitute).
//!
//! Global logger with a runtime-settable level (default `Info`, overridable
//! via the `ATA_LOG` environment variable: `error|warn|info|debug|trace`).
//! Thread-safe; writes to stderr so reports on stdout stay clean.

use std::fmt;
use std::io::Write;
use std::sync::atomic::{AtomicU8, Ordering};
use std::time::{SystemTime, UNIX_EPOCH};

/// Log severity, ordered from most to least severe.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    fn from_u8(v: u8) -> Level {
        match v {
            0 => Level::Error,
            1 => Level::Warn,
            2 => Level::Info,
            3 => Level::Debug,
            _ => Level::Trace,
        }
    }

    /// Parse from a string, case-insensitive.
    pub fn parse(s: &str) -> Option<Level> {
        match s.to_ascii_lowercase().as_str() {
            "error" => Some(Level::Error),
            "warn" | "warning" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            "trace" => Some(Level::Trace),
            _ => None,
        }
    }

    fn tag(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

impl fmt::Display for Level {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.tag().trim_end())
    }
}

static MAX_LEVEL: AtomicU8 = AtomicU8::new(u8::MAX); // u8::MAX = uninitialized

fn init_from_env() -> u8 {
    let lvl = std::env::var("ATA_LOG")
        .ok()
        .and_then(|s| Level::parse(&s))
        .unwrap_or(Level::Info);
    let v = lvl as u8;
    MAX_LEVEL.store(v, Ordering::Relaxed);
    v
}

/// Current maximum level that will be emitted.
pub fn max_level() -> Level {
    let v = MAX_LEVEL.load(Ordering::Relaxed);
    let v = if v == u8::MAX { init_from_env() } else { v };
    Level::from_u8(v)
}

/// Override the level programmatically (wins over `ATA_LOG`).
pub fn set_level(level: Level) {
    MAX_LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Whether `level` is currently enabled.
#[inline]
pub fn enabled(level: Level) -> bool {
    level <= max_level()
}

/// Core emit function — prefer the [`crate::log_info!`]-style macros.
pub fn emit(level: Level, module: &str, msg: fmt::Arguments<'_>) {
    if !enabled(level) {
        return;
    }
    let now = SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .unwrap_or_default();
    let secs = now.as_secs();
    let millis = now.subsec_millis();
    // Single write so concurrent threads do not interleave mid-line.
    let line = format!(
        "[{secs}.{millis:03} {} {module}] {msg}\n",
        level.tag()
    );
    let _ = std::io::stderr().write_all(line.as_bytes());
}

/// `log_error!(module, fmt, args...)`
#[macro_export]
macro_rules! log_error {
    ($module:expr, $($arg:tt)*) => {
        $crate::util::logging::emit($crate::util::logging::Level::Error, $module, format_args!($($arg)*))
    };
}

/// `log_warn!(module, fmt, args...)`
#[macro_export]
macro_rules! log_warn {
    ($module:expr, $($arg:tt)*) => {
        $crate::util::logging::emit($crate::util::logging::Level::Warn, $module, format_args!($($arg)*))
    };
}

/// `log_info!(module, fmt, args...)`
#[macro_export]
macro_rules! log_info {
    ($module:expr, $($arg:tt)*) => {
        $crate::util::logging::emit($crate::util::logging::Level::Info, $module, format_args!($($arg)*))
    };
}

/// `log_debug!(module, fmt, args...)`
#[macro_export]
macro_rules! log_debug {
    ($module:expr, $($arg:tt)*) => {
        $crate::util::logging::emit($crate::util::logging::Level::Debug, $module, format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels_order() {
        assert!(Level::Error < Level::Warn);
        assert!(Level::Warn < Level::Info);
        assert!(Level::Info < Level::Debug);
        assert!(Level::Debug < Level::Trace);
    }

    #[test]
    fn parse_levels() {
        assert_eq!(Level::parse("ERROR"), Some(Level::Error));
        assert_eq!(Level::parse("warn"), Some(Level::Warn));
        assert_eq!(Level::parse("Warning"), Some(Level::Warn));
        assert_eq!(Level::parse("nope"), None);
    }

    #[test]
    fn set_level_gates_enabled() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Trace);
        assert!(enabled(Level::Debug));
        set_level(Level::Info); // restore default-ish for other tests
    }
}
