//! Leveled, timestamped logging (the `log`/`env_logger` substitute).
//!
//! Global logger with a runtime-settable level (default `Info`, overridable
//! via the `ATA_LOG` environment variable: `error|warn|info|debug|trace`).
//! Thread-safe; writes to stderr so reports on stdout stay clean.
//!
//! Structured fields ride as a `key=value` suffix after the message
//! ([`emit_kv`] / the [`crate::log_kv!`] macro); traced scopes attach
//! `trace_id=...` this way so a grep for one request's trace id walks
//! its whole lifecycle. `ATA_LOG_FORMAT=json` (or
//! [`set_format`]`(Format::Json)`) switches every line to one JSON
//! object — same fields, machine-parseable, still one `write_all` per
//! line so concurrent threads never interleave mid-record.

use std::fmt;
use std::io::Write;
use std::sync::atomic::{AtomicU8, Ordering};
use std::time::{SystemTime, UNIX_EPOCH};

/// Log severity, ordered from most to least severe.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    fn from_u8(v: u8) -> Level {
        match v {
            0 => Level::Error,
            1 => Level::Warn,
            2 => Level::Info,
            3 => Level::Debug,
            _ => Level::Trace,
        }
    }

    /// Parse from a string, case-insensitive.
    pub fn parse(s: &str) -> Option<Level> {
        match s.to_ascii_lowercase().as_str() {
            "error" => Some(Level::Error),
            "warn" | "warning" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            "trace" => Some(Level::Trace),
            _ => None,
        }
    }

    fn tag(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

impl fmt::Display for Level {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.tag().trim_end())
    }
}

static MAX_LEVEL: AtomicU8 = AtomicU8::new(u8::MAX); // u8::MAX = uninitialized

fn init_from_env() -> u8 {
    let lvl = std::env::var("ATA_LOG")
        .ok()
        .and_then(|s| Level::parse(&s))
        .unwrap_or(Level::Info);
    let v = lvl as u8;
    MAX_LEVEL.store(v, Ordering::Relaxed);
    v
}

/// Current maximum level that will be emitted.
pub fn max_level() -> Level {
    let v = MAX_LEVEL.load(Ordering::Relaxed);
    let v = if v == u8::MAX { init_from_env() } else { v };
    Level::from_u8(v)
}

/// Override the level programmatically (wins over `ATA_LOG`).
pub fn set_level(level: Level) {
    MAX_LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Whether `level` is currently enabled.
#[inline]
pub fn enabled(level: Level) -> bool {
    level <= max_level()
}

/// Output format for every log line.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Format {
    /// `[secs.millis LEVEL module] message key=value ...`
    Text = 0,
    /// One JSON object per line: `{"ts":...,"level":...,"module":...,
    /// "msg":...,"key":"value",...}` — field values are rendered to
    /// strings, so wide u64s (trace ids) survive any JSON consumer.
    Json = 1,
}

static FORMAT: AtomicU8 = AtomicU8::new(u8::MAX); // u8::MAX = uninitialized

fn init_format_from_env() -> u8 {
    let fmt = match std::env::var("ATA_LOG_FORMAT").ok().as_deref() {
        Some(s) if s.eq_ignore_ascii_case("json") => Format::Json,
        _ => Format::Text,
    };
    let v = fmt as u8;
    FORMAT.store(v, Ordering::Relaxed);
    v
}

/// Current output format (`ATA_LOG_FORMAT=json` selects JSON).
pub fn format() -> Format {
    let v = FORMAT.load(Ordering::Relaxed);
    let v = if v == u8::MAX {
        init_format_from_env()
    } else {
        v
    };
    if v == Format::Json as u8 {
        Format::Json
    } else {
        Format::Text
    }
}

/// Override the output format programmatically (wins over the env var).
pub fn set_format(fmt: Format) {
    FORMAT.store(fmt as u8, Ordering::Relaxed);
}

/// Minimal JSON string escaping for log fields (quotes, backslashes,
/// control characters) — enough for any `Display` rendering.
fn json_escape_into(out: &mut String, s: &str) {
    out.push('"');
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Render one log record in the active format. Separated from the
/// stderr write so tests can assert on the exact line shape.
pub fn render_line(
    format: Format,
    secs: u64,
    millis: u32,
    level: Level,
    module: &str,
    msg: &str,
    fields: &[(&str, String)],
) -> String {
    match format {
        Format::Text => {
            let mut line = format!("[{secs}.{millis:03} {} {module}] {msg}", level.tag());
            for (k, v) in fields {
                line.push(' ');
                line.push_str(k);
                line.push('=');
                line.push_str(v);
            }
            line.push('\n');
            line
        }
        Format::Json => {
            let mut line = String::with_capacity(96);
            line.push_str(&format!("{{\"ts\":{secs}.{millis:03},\"level\":\"{level}\","));
            line.push_str("\"module\":");
            json_escape_into(&mut line, module);
            line.push_str(",\"msg\":");
            json_escape_into(&mut line, msg);
            for (k, v) in fields {
                line.push(',');
                json_escape_into(&mut line, k);
                line.push(':');
                json_escape_into(&mut line, v);
            }
            line.push_str("}\n");
            line
        }
    }
}

/// Core structured emit — message plus `key=value` fields. Prefer the
/// [`crate::log_kv!`] macro at call sites.
pub fn emit_kv(level: Level, module: &str, msg: fmt::Arguments<'_>, fields: &[(&str, String)]) {
    if !enabled(level) {
        return;
    }
    let now = SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .unwrap_or_default();
    let line = render_line(
        format(),
        now.as_secs(),
        now.subsec_millis(),
        level,
        module,
        &msg.to_string(),
        fields,
    );
    // Single write so concurrent threads do not interleave mid-line.
    let _ = std::io::stderr().write_all(line.as_bytes());
}

/// Core emit function — prefer the [`crate::log_info!`]-style macros.
pub fn emit(level: Level, module: &str, msg: fmt::Arguments<'_>) {
    emit_kv(level, module, msg, &[]);
}

/// `log_error!(module, fmt, args...)`
#[macro_export]
macro_rules! log_error {
    ($module:expr, $($arg:tt)*) => {
        $crate::util::logging::emit($crate::util::logging::Level::Error, $module, format_args!($($arg)*))
    };
}

/// `log_warn!(module, fmt, args...)`
#[macro_export]
macro_rules! log_warn {
    ($module:expr, $($arg:tt)*) => {
        $crate::util::logging::emit($crate::util::logging::Level::Warn, $module, format_args!($($arg)*))
    };
}

/// `log_info!(module, fmt, args...)`
#[macro_export]
macro_rules! log_info {
    ($module:expr, $($arg:tt)*) => {
        $crate::util::logging::emit($crate::util::logging::Level::Info, $module, format_args!($($arg)*))
    };
}

/// `log_debug!(module, fmt, args...)`
#[macro_export]
macro_rules! log_debug {
    ($module:expr, $($arg:tt)*) => {
        $crate::util::logging::emit($crate::util::logging::Level::Debug, $module, format_args!($($arg)*))
    };
}

/// Structured log line with `key=value` fields:
/// `log_kv!(Level::Info, module, { "trace_id" => trace, "peer" => addr }, fmt, args...)`.
/// Field values are rendered via `Display`; under `ATA_LOG_FORMAT=json`
/// each becomes a string field of the line's JSON object.
#[macro_export]
macro_rules! log_kv {
    ($level:expr, $module:expr, { $($k:literal => $v:expr),* $(,)? }, $($arg:tt)*) => {
        if $crate::util::logging::enabled($level) {
            $crate::util::logging::emit_kv(
                $level,
                $module,
                format_args!($($arg)*),
                &[$(($k, ::std::string::ToString::to_string(&$v))),*],
            )
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels_order() {
        assert!(Level::Error < Level::Warn);
        assert!(Level::Warn < Level::Info);
        assert!(Level::Info < Level::Debug);
        assert!(Level::Debug < Level::Trace);
    }

    #[test]
    fn parse_levels() {
        assert_eq!(Level::parse("ERROR"), Some(Level::Error));
        assert_eq!(Level::parse("warn"), Some(Level::Warn));
        assert_eq!(Level::parse("Warning"), Some(Level::Warn));
        assert_eq!(Level::parse("nope"), None);
    }

    #[test]
    fn set_level_gates_enabled() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Trace);
        assert!(enabled(Level::Debug));
        set_level(Level::Info); // restore default-ish for other tests
    }

    #[test]
    fn text_lines_append_key_value_suffix() {
        let fields = vec![
            ("trace_id", "18446744073709551615".to_string()),
            ("shard", "3".to_string()),
        ];
        let line = render_line(Format::Text, 12, 7, Level::Info, "coordinator", "drained", &fields);
        assert_eq!(
            line,
            "[12.007 INFO  coordinator] drained trace_id=18446744073709551615 shard=3\n"
        );
        // No fields → byte-identical to the historical plain format.
        let bare = render_line(Format::Text, 12, 7, Level::Info, "coordinator", "drained", &[]);
        assert_eq!(bare, "[12.007 INFO  coordinator] drained\n");
    }

    #[test]
    fn json_lines_are_one_parseable_object_each() {
        let fields = vec![("trace_id", "41".to_string())];
        let line = render_line(
            Format::Json,
            9,
            42,
            Level::Warn,
            "coordinator::server",
            "panic \"boom\"\nquarantined",
            &fields,
        );
        assert!(line.ends_with('\n'));
        assert_eq!(line.matches('\n').count(), 1, "one record, one line");
        let parsed = crate::util::json::Json::parse(line.trim_end()).expect("valid JSON");
        assert_eq!(parsed.get("level").and_then(Json::as_str), Some("WARN"));
        assert_eq!(
            parsed.get("module").and_then(Json::as_str),
            Some("coordinator::server")
        );
        assert_eq!(
            parsed.get("msg").and_then(Json::as_str),
            Some("panic \"boom\"\nquarantined")
        );
        assert_eq!(parsed.get("trace_id").and_then(Json::as_str), Some("41"));
        assert_eq!(parsed.get("ts").and_then(Json::as_f64), Some(9.042));
    }

    use crate::util::json::Json;
}
