//! General-purpose substrates built from scratch.
//!
//! The offline registry ships only the `xla` crate's dependency closure —
//! no `serde`, `clap`, `tokio`, `log` — so the framework's infrastructure
//! lives here:
//!
//! * [`json`] — a complete JSON value model, parser and encoder (metrics
//!   export, artifact manifests, golden test vectors, wire protocol).
//! * [`cli`] — declarative command-line parsing for the launcher.
//! * [`logging`] — leveled, timestamped logger with env control.
//! * [`pool`] — a worker threadpool (parallel experiment runs, coordinator
//!   shards, service connections).
//! * [`cpu`] — cache-line padding and opt-in shard→core pinning (raw
//!   `sched_setaffinity`, graceful no-op off Linux).
//! * [`signal`] — graceful-termination signal watching (raw
//!   `rt_sigprocmask` + `signalfd4`, graceful no-op off Linux).
//! * [`fmt`] — human-readable number/duration/bytes formatting for reports.

pub mod cli;
pub mod cpu;
pub mod fmt;
pub mod json;
pub mod logging;
pub mod pool;
pub mod signal;
