//! Worker threadpool (the `tokio`/`rayon` substitute for this crate)
//! and a reusable [`BufferPool`] for the coordinator's batched ingest.
//!
//! A fixed-size pool executing boxed closures from a shared queue. Supports
//! fire-and-forget jobs, scoped map over an input slice (used for the
//! 100-run experiment fan-out), and graceful shutdown on drop.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

/// Parked buffers plus a running total of their capacity, so the
/// hot-path park/unpark decisions are O(1).
struct FreeList<T> {
    bufs: Vec<Vec<T>>,
    /// Total capacity (in elements) across `bufs`.
    elems: usize,
}

impl<T> Default for FreeList<T> {
    fn default() -> FreeList<T> {
        FreeList {
            bufs: Vec::new(),
            elems: 0,
        }
    }
}

/// Shared free-list behind a [`BufferPool`].
struct PoolShared<T> {
    free: Mutex<FreeList<T>>,
    /// Buffers parked beyond this bound are dropped instead of pooled.
    max_pooled: usize,
    /// Largest per-buffer capacity (elements) worth parking.
    max_buf_elems: usize,
    /// Total idle capacity budget (elements) across the pool.
    max_total_elems: usize,
    /// Takes served by a recycled allocation (vs fresh `Vec`s below) —
    /// `reuse_ratio` is the pool's effectiveness gauge.
    hits: AtomicU64,
    /// Takes that had to allocate fresh.
    misses: AtomicU64,
}

/// A pool of reusable `Vec<T>` allocations (`T = f64` by default).
///
/// The coordinator's batched ingest ([`push_many`]) copies each wire
/// batch into a pooled `f64` buffer, ships it through a shard queue, and
/// the worker's drop returns the allocation here — so steady-state
/// batched ingest performs **zero** heap allocation per message,
/// regardless of batch size (capacity is retained across reuses). The
/// TCP server routes its per-connection frame read/write scratch through
/// a `BufferPool<u8>` of the same design, so connection churn and
/// response encoding reuse parked byte buffers too.
///
/// Clones share the same free list, so one pool can serve producers on
/// many threads.
///
/// [`push_many`]: crate::coordinator::Coordinator::push_many
pub struct BufferPool<T = f64> {
    shared: Arc<PoolShared<T>>,
}

impl<T> Clone for BufferPool<T> {
    fn clone(&self) -> BufferPool<T> {
        BufferPool {
            shared: Arc::clone(&self.shared),
        }
    }
}

impl<T> BufferPool<T> {
    /// A pool retaining at most `max_pooled` idle buffers, with the
    /// default capacity caps ([`MAX_POOLED_CAPACITY`],
    /// [`MAX_POOLED_TOTAL`]).
    pub fn new(max_pooled: usize) -> BufferPool<T> {
        BufferPool::with_caps(max_pooled, MAX_POOLED_CAPACITY, MAX_POOLED_TOTAL)
    }

    /// A pool with explicit retention caps: at most `max_pooled` idle
    /// buffers, none larger than `max_buf_elems` capacity, totalling at
    /// most `max_total_elems`. The WAL replay path uses this to run a
    /// larger pool than the ingest default (recovery streams millions of
    /// batch buffers through the shard queues back-to-back), without
    /// patching the crate-wide constants.
    pub fn with_caps(
        max_pooled: usize,
        max_buf_elems: usize,
        max_total_elems: usize,
    ) -> BufferPool<T> {
        BufferPool {
            shared: Arc::new(PoolShared {
                free: Mutex::new(FreeList::default()),
                max_pooled: max_pooled.max(1),
                max_buf_elems: max_buf_elems.max(1),
                max_total_elems: max_total_elems.max(1),
                hits: AtomicU64::new(0),
                misses: AtomicU64::new(0),
            }),
        }
    }

    /// A pooled empty buffer (recycles a parked allocation when one is
    /// available); fill through [`PooledBuf::as_mut_vec`].
    pub fn take_empty(&self) -> PooledBuf<T> {
        let mut v = {
            let mut free = self.shared.free.lock().expect("buffer pool");
            match free.bufs.pop() {
                Some(v) => {
                    free.elems -= v.capacity();
                    self.shared.hits.fetch_add(1, Ordering::Relaxed);
                    v
                }
                None => {
                    self.shared.misses.fetch_add(1, Ordering::Relaxed);
                    Vec::new()
                }
            }
        };
        v.clear();
        PooledBuf {
            data: v,
            home: Some(Arc::clone(&self.shared)),
        }
    }

    /// Buffers currently parked (tests/metrics).
    pub fn idle(&self) -> usize {
        self.shared.free.lock().expect("buffer pool").bufs.len()
    }

    /// Takes served by a recycled allocation.
    pub fn hits(&self) -> u64 {
        self.shared.hits.load(Ordering::Relaxed)
    }

    /// Takes that allocated fresh.
    pub fn misses(&self) -> u64 {
        self.shared.misses.load(Ordering::Relaxed)
    }

    /// `hits / (hits + misses)` — 0.0 before the first take. The
    /// coordinator exports this as `gauge.pool_reuse_ratio`; sustained
    /// low values mean the retention caps are too tight for the load.
    pub fn reuse_ratio(&self) -> f64 {
        let h = self.hits();
        let total = h + self.misses();
        if total == 0 {
            0.0
        } else {
            h as f64 / total as f64
        }
    }
}

impl<T: Clone> BufferPool<T> {
    /// A pooled buffer holding a copy of `data` (recycles a parked
    /// allocation when one is available).
    pub fn take(&self, data: &[T]) -> PooledBuf<T> {
        let mut buf = self.take_empty();
        buf.data.extend_from_slice(data);
        buf
    }
}

impl<T: Clone + Default> BufferPool<T> {
    /// A pooled buffer of exactly `len` default-valued (zeroed) elements
    /// — the output-side twin of [`BufferPool::take`], used by the
    /// coordinator's snapshot path so steady-state reads allocate
    /// nothing.
    pub fn take_len(&self, len: usize) -> PooledBuf<T> {
        let mut buf = self.take_empty();
        if buf.data.capacity() < len {
            // Fresh (or growing) allocation: write the WHOLE capacity
            // once, here, then trim. A plain `resize(len)` would leave
            // the spare capacity's pages untouched, deferring their
            // soft page faults to the first hot-path write; pre-touching
            // moves that cost to the (already slow) miss path. Recycled
            // buffers skip this — their pages are already mapped.
            buf.data.reserve_exact(len);
            let cap = buf.data.capacity();
            buf.data.resize(cap, T::default());
        }
        buf.data.resize(len, T::default());
        buf
    }
}

/// A buffer that returns its allocation to its [`BufferPool`] on drop.
/// Dereferences to `[T]` (`T = f64` by default).
pub struct PooledBuf<T = f64> {
    data: Vec<T>,
    home: Option<Arc<PoolShared<T>>>,
}

impl<T> PooledBuf<T> {
    /// Wrap an owned vector without pooling (the allocation is simply
    /// dropped at the end) — the single-sample `push` path.
    pub fn unpooled(data: Vec<T>) -> PooledBuf<T> {
        PooledBuf { data, home: None }
    }

    /// Take the contents out as a plain `Vec` (the allocation leaves the
    /// pool for good).
    pub fn into_vec(mut self) -> Vec<T> {
        self.home = None;
        std::mem::take(&mut self.data)
    }

    /// The backing `Vec`, for callers that need to grow/shrink in place
    /// (the wire framing path resizes to each frame's payload length).
    /// Capacity changes are accounted when the buffer is parked.
    pub fn as_mut_vec(&mut self) -> &mut Vec<T> {
        &mut self.data
    }
}

impl<T> std::ops::Deref for PooledBuf<T> {
    type Target = [T];
    fn deref(&self) -> &[T] {
        &self.data
    }
}

impl<T> std::ops::DerefMut for PooledBuf<T> {
    fn deref_mut(&mut self) -> &mut [T] {
        &mut self.data
    }
}

/// Clones are unpooled: a copy escaping the hot path must not compete
/// for the pool's parked allocations.
impl<T: Clone> Clone for PooledBuf<T> {
    fn clone(&self) -> PooledBuf<T> {
        PooledBuf {
            data: self.data.clone(),
            home: None,
        }
    }
}

impl<T: std::fmt::Debug> std::fmt::Debug for PooledBuf<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        self.data.fmt(f)
    }
}

impl<T: PartialEq> PartialEq for PooledBuf<T> {
    fn eq(&self, other: &PooledBuf<T>) -> bool {
        self.data == other.data
    }
}

impl<T: PartialEq> PartialEq<Vec<T>> for PooledBuf<T> {
    fn eq(&self, other: &Vec<T>) -> bool {
        self.data == *other
    }
}

impl<T: PartialEq> PartialEq<[T]> for PooledBuf<T> {
    fn eq(&self, other: &[T]) -> bool {
        self.data[..] == *other
    }
}

impl<T: PartialEq> PartialEq<PooledBuf<T>> for Vec<T> {
    fn eq(&self, other: &PooledBuf<T>) -> bool {
        *self == other.data
    }
}

/// Default largest per-buffer capacity (in elements) worth parking: one
/// burst of giant batches must not pin its allocations in the pool
/// forever (8 MiB per buffer at f64). Override per pool with
/// [`BufferPool::with_caps`].
pub const MAX_POOLED_CAPACITY: usize = 1 << 20;

/// Default total idle capacity budget (in elements) across a pool: even
/// `max_pooled` buffers individually under the cap must not add up to
/// hundreds of retained MiB (4M floats = 32 MiB). Override per pool
/// with [`BufferPool::with_caps`].
pub const MAX_POOLED_TOTAL: usize = 4 << 20;

impl<T> Drop for PooledBuf<T> {
    fn drop(&mut self) {
        if let Some(home) = self.home.take() {
            let cap = self.data.capacity();
            if cap > home.max_buf_elems {
                return; // oversized: let the allocation die
            }
            let mut free = home.free.lock().expect("buffer pool");
            if free.bufs.len() < home.max_pooled && free.elems + cap <= home.max_total_elems {
                free.elems += cap;
                free.bufs.push(std::mem::take(&mut self.data));
            }
        }
    }
}

type Job = Box<dyn FnOnce() + Send + 'static>;

enum Message {
    Run(Job),
    Shutdown,
}

/// Fixed-size worker pool.
pub struct ThreadPool {
    sender: mpsc::Sender<Message>,
    workers: Vec<thread::JoinHandle<()>>,
    size: usize,
}

impl ThreadPool {
    /// Create a pool with `size` workers (min 1).
    pub fn new(size: usize) -> ThreadPool {
        let size = size.max(1);
        let (sender, receiver) = mpsc::channel::<Message>();
        let receiver = Arc::new(Mutex::new(receiver));
        let mut workers = Vec::with_capacity(size);
        for i in 0..size {
            let rx = Arc::clone(&receiver);
            let handle = thread::Builder::new()
                .name(format!("ata-worker-{i}"))
                .spawn(move || loop {
                    let msg = {
                        let guard = rx.lock().expect("pool queue poisoned");
                        guard.recv()
                    };
                    match msg {
                        Ok(Message::Run(job)) => {
                            // A panicking job must not kill the worker.
                            let _ = catch_unwind(AssertUnwindSafe(job));
                        }
                        Ok(Message::Shutdown) | Err(_) => break,
                    }
                })
                .expect("spawn worker");
            workers.push(handle);
        }
        ThreadPool {
            sender,
            workers,
            size,
        }
    }

    /// Pool sized to the machine (`available_parallelism`, capped).
    pub fn with_default_size() -> ThreadPool {
        let n = thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(4)
            .min(32);
        ThreadPool::new(n)
    }

    /// Number of workers.
    pub fn size(&self) -> usize {
        self.size
    }

    /// Submit a fire-and-forget job.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, job: F) {
        self.sender
            .send(Message::Run(Box::new(job)))
            .expect("pool has shut down");
    }

    /// Apply `f` to `0..n` in parallel and collect results in input order.
    ///
    /// `f` must be `Sync` because all workers share it; results are sent
    /// back over a channel tagged with their index.
    pub fn map_indexed<T, F>(&self, n: usize, f: F) -> Vec<T>
    where
        T: Send + 'static,
        F: Fn(usize) -> T + Send + Sync + 'static,
    {
        let f = Arc::new(f);
        let (tx, rx) = mpsc::channel::<(usize, T)>();
        for i in 0..n {
            let f = Arc::clone(&f);
            let tx = tx.clone();
            self.execute(move || {
                let r = f(i);
                let _ = tx.send((i, r));
            });
        }
        drop(tx);
        let mut slots: Vec<Option<T>> = (0..n).map(|_| None).collect();
        let mut received = 0usize;
        while received < n {
            match rx.recv() {
                Ok((i, r)) => {
                    slots[i] = Some(r);
                    received += 1;
                }
                Err(_) => panic!(
                    "worker dropped result channel — a parallel job panicked \
                     ({received}/{n} results received)"
                ),
            }
        }
        slots.into_iter().map(|s| s.expect("slot filled")).collect()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        for _ in 0..self.workers.len() {
            let _ = self.sender.send(Message::Shutdown);
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        let (tx, rx) = mpsc::channel();
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            let tx = tx.clone();
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
                let _ = tx.send(());
            });
        }
        for _ in 0..100 {
            rx.recv_timeout(std::time::Duration::from_secs(10)).unwrap();
        }
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn map_indexed_preserves_order() {
        let pool = ThreadPool::new(8);
        let out = pool.map_indexed(64, |i| i * i);
        assert_eq!(out, (0..64).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn map_indexed_empty() {
        let pool = ThreadPool::new(2);
        let out: Vec<u32> = pool.map_indexed(0, |_| 1);
        assert!(out.is_empty());
    }

    #[test]
    fn survives_panicking_job() {
        let pool = ThreadPool::new(2);
        pool.execute(|| panic!("boom"));
        // The pool must still process subsequent jobs.
        let out = pool.map_indexed(8, |i| i + 1);
        assert_eq!(out, (1..=8).collect::<Vec<_>>());
    }

    #[test]
    fn buffer_pool_recycles_allocations() {
        let pool = BufferPool::new(4);
        assert_eq!(pool.idle(), 0);
        let a = pool.take(&[1.0, 2.0, 3.0]);
        assert_eq!(&*a, &[1.0, 2.0, 3.0]);
        drop(a);
        assert_eq!(pool.idle(), 1);
        // Reuse must not leak previous contents.
        let b = pool.take(&[9.0]);
        assert_eq!(pool.idle(), 0);
        assert_eq!(&*b, &[9.0]);
        drop(b);
        assert_eq!(pool.idle(), 1);
    }

    #[test]
    fn buffer_pool_bounds_idle_buffers() {
        let pool = BufferPool::new(2);
        let bufs: Vec<_> = (0..5).map(|i| pool.take(&[i as f64])).collect();
        drop(bufs);
        assert_eq!(pool.idle(), 2);
    }

    #[test]
    fn buffer_pool_drops_oversized_allocations() {
        let pool = BufferPool::new(4);
        let big = pool.take(&vec![0.0; MAX_POOLED_CAPACITY + 1]);
        drop(big);
        assert_eq!(pool.idle(), 0, "oversized buffers must not be parked");
    }

    #[test]
    fn with_caps_overrides_retention_bounds() {
        // A replay-sized pool parks buffers the default caps would drop…
        let big_pool = BufferPool::with_caps(4, 2 * MAX_POOLED_CAPACITY, 8 * MAX_POOLED_CAPACITY);
        let big = big_pool.take(&vec![0.0; MAX_POOLED_CAPACITY + 1]);
        drop(big);
        assert_eq!(big_pool.idle(), 1);
        // …and a tiny pool drops buffers the defaults would keep, both
        // per-buffer and in total.
        let tiny = BufferPool::with_caps(8, 4, 6);
        drop(tiny.take(&[0.0; 5])); // over the per-buffer cap
        assert_eq!(tiny.idle(), 0);
        drop(tiny.take(&[0.0; 4]));
        drop(tiny.take(&[0.0; 4])); // 4 + 4 > total budget of 6
        assert_eq!(tiny.idle(), 1);
    }

    #[test]
    fn pool_counts_hits_misses_and_reuse_ratio() {
        let pool = BufferPool::new(4);
        assert_eq!(pool.reuse_ratio(), 0.0);
        drop(pool.take(&[1.0])); // miss (cold), then parked
        let b = pool.take(&[2.0]); // hit
        assert_eq!(pool.hits(), 1);
        assert_eq!(pool.misses(), 1);
        assert_eq!(pool.reuse_ratio(), 0.5);
        let c = pool.take(&[3.0]); // miss (the only parked buf is out)
        drop(b);
        drop(c);
        drop(pool.take_len(8)); // hit, and pre-touches its grown capacity
        assert_eq!(pool.hits(), 2);
        assert_eq!(pool.misses(), 2);
        // Clones share the free list AND the accounting.
        let alias = pool.clone();
        assert_eq!(alias.hits(), 2);
    }

    #[test]
    fn take_len_zeroes_and_clone_is_unpooled() {
        let pool = BufferPool::new(2);
        let mut b = pool.take_len(3);
        assert_eq!(b, vec![0.0; 3]);
        b[1] = 5.0;
        let c = b.clone();
        assert_eq!(c, b);
        drop(b);
        assert_eq!(pool.idle(), 1);
        drop(c); // clone is unpooled: must not be parked
        assert_eq!(pool.idle(), 1);
        // Reuse must re-zero.
        assert_eq!(pool.take_len(2), vec![0.0; 2]);
        // into_vec removes the allocation from circulation.
        let v = pool.take(&[1.0]).into_vec();
        assert_eq!(v, vec![1.0]);
        assert_eq!(pool.idle(), 0);
    }

    #[test]
    fn byte_pool_recycles_through_as_mut_vec() {
        // The wire framing path: resize/extend through the Vec handle,
        // park on drop, reuse without leaking prior contents.
        let pool: BufferPool<u8> = BufferPool::new(2);
        let mut b = pool.take_empty();
        b.as_mut_vec().resize(4, 0);
        b.as_mut_vec().extend_from_slice(b"xy");
        assert_eq!(&*b, &[0, 0, 0, 0, b'x', b'y']);
        drop(b);
        assert_eq!(pool.idle(), 1);
        let c = pool.take(b"z");
        assert_eq!(pool.idle(), 0);
        assert_eq!(&*c, b"z");
    }

    #[test]
    fn unpooled_buf_is_plain() {
        let b = PooledBuf::unpooled(vec![5.0, 6.0]);
        assert_eq!(&*b, &[5.0, 6.0]);
        drop(b); // must not panic or pool anything
    }

    #[test]
    fn shutdown_joins_workers() {
        let pool = ThreadPool::new(2);
        let c = Arc::new(AtomicUsize::new(0));
        for _ in 0..10 {
            let c = Arc::clone(&c);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // must not hang
    }
}
