//! Worker threadpool (the `tokio`/`rayon` substitute for this crate).
//!
//! A fixed-size pool executing boxed closures from a shared queue. Supports
//! fire-and-forget jobs, scoped map over an input slice (used for the
//! 100-run experiment fan-out), and graceful shutdown on drop.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

enum Message {
    Run(Job),
    Shutdown,
}

/// Fixed-size worker pool.
pub struct ThreadPool {
    sender: mpsc::Sender<Message>,
    workers: Vec<thread::JoinHandle<()>>,
    size: usize,
}

impl ThreadPool {
    /// Create a pool with `size` workers (min 1).
    pub fn new(size: usize) -> ThreadPool {
        let size = size.max(1);
        let (sender, receiver) = mpsc::channel::<Message>();
        let receiver = Arc::new(Mutex::new(receiver));
        let mut workers = Vec::with_capacity(size);
        for i in 0..size {
            let rx = Arc::clone(&receiver);
            let handle = thread::Builder::new()
                .name(format!("ata-worker-{i}"))
                .spawn(move || loop {
                    let msg = {
                        let guard = rx.lock().expect("pool queue poisoned");
                        guard.recv()
                    };
                    match msg {
                        Ok(Message::Run(job)) => {
                            // A panicking job must not kill the worker.
                            let _ = catch_unwind(AssertUnwindSafe(job));
                        }
                        Ok(Message::Shutdown) | Err(_) => break,
                    }
                })
                .expect("spawn worker");
            workers.push(handle);
        }
        ThreadPool {
            sender,
            workers,
            size,
        }
    }

    /// Pool sized to the machine (`available_parallelism`, capped).
    pub fn with_default_size() -> ThreadPool {
        let n = thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(4)
            .min(32);
        ThreadPool::new(n)
    }

    /// Number of workers.
    pub fn size(&self) -> usize {
        self.size
    }

    /// Submit a fire-and-forget job.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, job: F) {
        self.sender
            .send(Message::Run(Box::new(job)))
            .expect("pool has shut down");
    }

    /// Apply `f` to `0..n` in parallel and collect results in input order.
    ///
    /// `f` must be `Sync` because all workers share it; results are sent
    /// back over a channel tagged with their index.
    pub fn map_indexed<T, F>(&self, n: usize, f: F) -> Vec<T>
    where
        T: Send + 'static,
        F: Fn(usize) -> T + Send + Sync + 'static,
    {
        let f = Arc::new(f);
        let (tx, rx) = mpsc::channel::<(usize, T)>();
        for i in 0..n {
            let f = Arc::clone(&f);
            let tx = tx.clone();
            self.execute(move || {
                let r = f(i);
                let _ = tx.send((i, r));
            });
        }
        drop(tx);
        let mut slots: Vec<Option<T>> = (0..n).map(|_| None).collect();
        let mut received = 0usize;
        while received < n {
            match rx.recv() {
                Ok((i, r)) => {
                    slots[i] = Some(r);
                    received += 1;
                }
                Err(_) => panic!(
                    "worker dropped result channel — a parallel job panicked \
                     ({received}/{n} results received)"
                ),
            }
        }
        slots.into_iter().map(|s| s.expect("slot filled")).collect()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        for _ in 0..self.workers.len() {
            let _ = self.sender.send(Message::Shutdown);
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        let (tx, rx) = mpsc::channel();
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            let tx = tx.clone();
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
                let _ = tx.send(());
            });
        }
        for _ in 0..100 {
            rx.recv_timeout(std::time::Duration::from_secs(10)).unwrap();
        }
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn map_indexed_preserves_order() {
        let pool = ThreadPool::new(8);
        let out = pool.map_indexed(64, |i| i * i);
        assert_eq!(out, (0..64).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn map_indexed_empty() {
        let pool = ThreadPool::new(2);
        let out: Vec<u32> = pool.map_indexed(0, |_| 1);
        assert!(out.is_empty());
    }

    #[test]
    fn survives_panicking_job() {
        let pool = ThreadPool::new(2);
        pool.execute(|| panic!("boom"));
        // The pool must still process subsequent jobs.
        let out = pool.map_indexed(8, |i| i + 1);
        assert_eq!(out, (1..=8).collect::<Vec<_>>());
    }

    #[test]
    fn shutdown_joins_workers() {
        let pool = ThreadPool::new(2);
        let c = Arc::new(AtomicUsize::new(0));
        for _ in 0..10 {
            let c = Arc::clone(&c);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // must not hang
    }
}
