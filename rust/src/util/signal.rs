//! Graceful-termination signals without `libc`: block `SIGTERM` and
//! `SIGINT`, then wait for one on a `signalfd` so the serve loop can
//! drain connections and force a final WAL commit instead of dying
//! mid-frame.
//!
//! Like [`crate::util::cpu`], this talks to the kernel directly
//! (`rt_sigprocmask` + `signalfd4` + `read`) on Linux x86_64/aarch64
//! and degrades gracefully elsewhere: [`termination_watcher`] returns
//! `None` and the caller keeps the old block-until-killed behaviour.
//!
//! Ordering matters: create the watcher **before** spawning worker
//! threads. The signal mask is inherited by threads spawned afterwards,
//! so a process-directed `SIGTERM` stays queued on the `signalfd`
//! instead of being delivered to (and killing) an arbitrary worker.

/// Which termination signal arrived.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TermSignal {
    /// `SIGINT` (Ctrl-C).
    Interrupt,
    /// `SIGTERM` (orchestrator shutdown).
    Terminate,
}

impl TermSignal {
    pub fn label(&self) -> &'static str {
        match self {
            TermSignal::Interrupt => "SIGINT",
            TermSignal::Terminate => "SIGTERM",
        }
    }
}

const SIGINT: u32 = 2;
const SIGTERM: u32 = 15;

/// A blocked-signal file descriptor; [`TermWatcher::wait`] blocks until
/// `SIGTERM`/`SIGINT` arrives.
pub struct TermWatcher {
    fd: i32,
}

/// Block `SIGTERM`+`SIGINT` for this thread (and every thread spawned
/// after) and open a `signalfd` watching them. `None` on unsupported
/// targets or kernel refusal — callers fall back to plain
/// block-until-killed.
pub fn termination_watcher() -> Option<TermWatcher> {
    imp::open().map(|fd| TermWatcher { fd })
}

impl TermWatcher {
    /// Block until a termination signal arrives. On an unexpected
    /// `signalfd` read failure the thread parks forever — identical to
    /// the pre-signal-handling behaviour (external kill).
    pub fn wait(&self) -> TermSignal {
        imp::wait(self.fd)
    }
}

impl Drop for TermWatcher {
    fn drop(&mut self) {
        imp::close(self.fd);
    }
}

#[cfg(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))]
mod imp {
    use super::{TermSignal, SIGINT, SIGTERM};

    #[cfg(target_arch = "x86_64")]
    mod nr {
        pub const READ: isize = 0;
        pub const CLOSE: isize = 3;
        pub const RT_SIGPROCMASK: isize = 14;
        pub const SIGNALFD4: isize = 289;
    }
    #[cfg(target_arch = "aarch64")]
    mod nr {
        pub const READ: isize = 63;
        pub const CLOSE: isize = 57;
        pub const RT_SIGPROCMASK: isize = 135;
        pub const SIGNALFD4: isize = 74;
    }

    #[cfg(target_arch = "x86_64")]
    unsafe fn syscall4(n: isize, a: usize, b: usize, c: usize, d: usize) -> isize {
        let ret: isize;
        core::arch::asm!(
            "syscall",
            inlateout("rax") n => ret,
            in("rdi") a,
            in("rsi") b,
            in("rdx") c,
            in("r10") d,
            lateout("rcx") _,
            lateout("r11") _,
            options(nostack)
        );
        ret
    }

    #[cfg(target_arch = "aarch64")]
    unsafe fn syscall4(n: isize, a: usize, b: usize, c: usize, d: usize) -> isize {
        let ret: isize;
        core::arch::asm!(
            "svc 0",
            in("x8") n,
            inlateout("x0") a => ret,
            in("x1") b,
            in("x2") c,
            in("x3") d,
            options(nostack)
        );
        ret
    }

    /// The kernel sigset: a u64 bitmask, bit `signo - 1`.
    const MASK: u64 = (1 << (SIGINT - 1)) | (1 << (SIGTERM - 1));
    const SIG_BLOCK: usize = 0;
    const SIGSET_LEN: usize = 8;
    const EINTR: isize = -4;

    pub fn open() -> Option<i32> {
        let mask = MASK;
        let mask_ptr = &mask as *const u64 as usize;
        // rt_sigprocmask(SIG_BLOCK, &mask, NULL, 8)
        let ret = unsafe { syscall4(nr::RT_SIGPROCMASK, SIG_BLOCK, mask_ptr, 0, SIGSET_LEN) };
        if ret != 0 {
            return None;
        }
        // signalfd4(-1 /* new fd */, &mask, 8, 0 /* no flags */)
        let fd = unsafe { syscall4(nr::SIGNALFD4, usize::MAX, mask_ptr, SIGSET_LEN, 0) };
        (fd >= 0).then_some(fd as i32)
    }

    pub fn wait(fd: i32) -> TermSignal {
        // struct signalfd_siginfo is 128 bytes; ssi_signo is the
        // leading u32. Partial reads never happen (the kernel returns
        // whole records).
        let mut buf = [0u8; 128];
        loop {
            let ret = unsafe {
                syscall4(nr::READ, fd as usize, buf.as_mut_ptr() as usize, buf.len(), 0)
            };
            if ret == EINTR {
                continue;
            }
            if ret != buf.len() as isize {
                // Unreadable signalfd: behave like the old serve loop
                // and simply block until the process is killed.
                loop {
                    std::thread::park();
                }
            }
            let signo = u32::from_ne_bytes([buf[0], buf[1], buf[2], buf[3]]);
            return match signo {
                SIGINT => TermSignal::Interrupt,
                _ => TermSignal::Terminate,
            };
        }
    }

    pub fn close(fd: i32) {
        unsafe {
            syscall4(nr::CLOSE, fd as usize, 0, 0, 0);
        }
    }
}

#[cfg(not(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64"))))]
mod imp {
    use super::TermSignal;

    pub fn open() -> Option<i32> {
        None
    }

    pub fn wait(_fd: i32) -> TermSignal {
        // Unreachable: open() never hands out a watcher here.
        loop {
            std::thread::park();
        }
    }

    pub fn close(_fd: i32) {}
}

#[cfg(test)]
mod tests {
    use super::*;

    // NOTE: no test creates a watcher — blocking SIGINT/SIGTERM
    // process-wide would leak into every other test in the harness
    // (they share one process) and make the suite unkillable with
    // Ctrl-C. The syscall path is exercised end-to-end by the serve
    // binary; here we only pin the pure pieces.

    #[test]
    fn labels_and_signal_numbers() {
        assert_eq!(TermSignal::Interrupt.label(), "SIGINT");
        assert_eq!(TermSignal::Terminate.label(), "SIGTERM");
        assert_eq!(SIGINT, 2);
        assert_eq!(SIGTERM, 15);
    }
}
