//! Property tests of the anytime analytics layer: every estimator's
//! STREAMED moments (mean, weighted variance, ESS) must match an O(n)
//! batch recomputation over its reconstructed weight profile to 1e-9,
//! be shift/scale-equivariant, collapse to zero variance on constant
//! streams, and combine associatively under the parallel-Welford merge.

use ata::analytics::{self, StatSnapshot, DEFAULT_Z};
use ata::averagers::{reconstruct_weights, AveragerSpec, WindowKind};
use ata::testkit::Runner;
use std::sync::Arc;

/// Every `AveragerSpec` variant, both window kinds where applicable —
/// the full 8-estimator matrix the acceptance criteria name.
fn all_specs() -> Vec<AveragerSpec> {
    vec![
        AveragerSpec::Exp { gamma: 0.85 },
        AveragerSpec::ExpK { k: 12 },
        AveragerSpec::Gea { c: 0.5 },
        AveragerSpec::Awa {
            window: WindowKind::Fixed { k: 9 },
            accumulators: 2,
        },
        AveragerSpec::Awa {
            window: WindowKind::Growing { c: 0.4 },
            accumulators: 3,
        },
        AveragerSpec::True {
            window: WindowKind::Fixed { k: 11 },
        },
        AveragerSpec::True {
            window: WindowKind::Growing { c: 0.5 },
        },
        AveragerSpec::Raw {
            c: 0.5,
            total_steps: 200,
        },
        AveragerSpec::Restart {
            window: WindowKind::Fixed { k: 7 },
        },
        AveragerSpec::Eh {
            window: WindowKind::Fixed { k: 40 },
            eps: 0.1,
        },
    ]
}

/// Deterministic dim-`d` test stream (same value per dim offset).
fn sample(t: u64, i: usize) -> f64 {
    ((t as f64) * 0.379 + (i as f64) * 1.1).sin() * 3.0 + ((t as f64) * 0.05).cos()
}

fn close(got: f64, want: f64, tol: f64, ctx: &str) {
    assert!(
        (got - want).abs() <= tol * want.abs().max(1.0),
        "{ctx}: got {got}, want {want}"
    );
}

/// The acceptance criterion: streamed variance/ESS equal an O(n) batch
/// recomputation of the same weighted tail — the weights reconstructed
/// generically by unit-impulse replay, so the closed forms inside each
/// estimator are cross-checked against ground truth.
#[test]
fn streamed_moments_match_batch_recomputation_every_spec() {
    let d = 2usize;
    let checkpoints = [1u64, 2, 3, 5, 13, 40, 90, 160];
    for spec in all_specs() {
        let label = spec.label();
        let mut avg = spec.build(d).unwrap();
        // Mixed scalar/batched feeding so both ingest paths contribute.
        let mut fed = 0u64;
        let mut xs: Vec<Vec<f64>> = Vec::new(); // per-step samples
        for &cp in &checkpoints {
            let run_len = (cp - fed) as usize;
            let mut flat = Vec::with_capacity(run_len * d);
            for s in 0..run_len {
                let t = fed + s as u64 + 1;
                let x: Vec<f64> = (0..d).map(|i| sample(t, i)).collect();
                flat.extend_from_slice(&x);
                xs.push(x);
            }
            if run_len % 2 == 1 && run_len > 0 {
                avg.observe(&flat[..d]);
                if run_len > 1 {
                    avg.observe_many(&flat[d..], run_len - 1);
                }
            } else if run_len > 0 {
                avg.observe_many(&flat, run_len);
            }
            fed = cp;

            // Batch oracle: α from unit-impulse reconstruction.
            let w = reconstruct_weights(&spec, cp)
                .unwrap_or_else(|e| panic!("{label}: weights at t={cp}: {e}"));
            assert_eq!(w.len(), cp as usize);
            let sum_sq: f64 = w.iter().map(|&a| a * a).sum();
            let want_ess = 1.0 / sum_sq;
            let (mut mean, mut var) = (vec![0.0; d], vec![0.0; d]);
            let ess = avg
                .moments_into(&mut mean, &mut var)
                .unwrap_or_else(|| panic!("{label}: no moments at t={cp}"));
            close(ess, want_ess, 1e-9, &format!("{label} t={cp} ess"));
            for dim in 0..d {
                let want_mean: f64 =
                    w.iter().zip(&xs).map(|(&a, x)| a * x[dim]).sum();
                let want_var: f64 = w
                    .iter()
                    .zip(&xs)
                    .map(|(&a, x)| a * (x[dim] - want_mean) * (x[dim] - want_mean))
                    .sum();
                close(
                    mean[dim],
                    want_mean,
                    1e-9,
                    &format!("{label} t={cp} mean[{dim}]"),
                );
                close(
                    var[dim],
                    want_var,
                    1e-9,
                    &format!("{label} t={cp} var[{dim}]"),
                );
            }
            // The moment mean is the estimate itself.
            let value = avg.value().expect("value");
            for dim in 0..d {
                close(
                    mean[dim],
                    value[dim],
                    1e-12,
                    &format!("{label} t={cp} mean==value[{dim}]"),
                );
            }
        }
    }
}

/// x → a·x + b must map mean → a·mean + b, variance → a²·variance, and
/// leave the ESS untouched (the weights don't see the data).
#[test]
fn moments_are_shift_scale_equivariant() {
    let transforms = [(2.5, -1.75), (-0.5, 3.0), (1.0, 100.0)];
    for spec in all_specs() {
        let label = spec.label();
        for &(a, b) in &transforms {
            let mut base = spec.build(1).unwrap();
            let mut mapped = spec.build(1).unwrap();
            for t in 1..=150u64 {
                let x = sample(t, 0);
                base.observe_scalar(x);
                mapped.observe_scalar(a * x + b);
            }
            let (mut m0, mut v0) = ([0.0], [0.0]);
            let (mut m1, mut v1) = ([0.0], [0.0]);
            let e0 = base.moments_into(&mut m0, &mut v0).expect("base moments");
            let e1 = mapped.moments_into(&mut m1, &mut v1).expect("mapped moments");
            close(e1, e0, 1e-12, &format!("{label} a={a} ess"));
            close(m1[0], a * m0[0] + b, 1e-9, &format!("{label} a={a} mean"));
            close(v1[0], a * a * v0[0], 1e-7, &format!("{label} a={a} var"));
        }
    }
}

/// A constant stream is a fixed point with exactly zero spread.
#[test]
fn constant_stream_variance_is_zero_every_spec() {
    for spec in all_specs() {
        let label = spec.label();
        let mut avg = spec.build(2).unwrap();
        for _ in 0..300 {
            avg.observe(&[7.5, -2.25]);
        }
        let (mut m, mut v) = ([0.0; 2], [0.0; 2]);
        let ess = avg.moments_into(&mut m, &mut v).expect("moments");
        close(m[0], 7.5, 1e-9, &format!("{label} mean[0]"));
        close(m[1], -2.25, 1e-9, &format!("{label} mean[1]"));
        assert!(
            v[0] < 1e-9 && v[1] < 1e-9,
            "{label}: constant stream variance {v:?}"
        );
        assert!(
            ess >= 1.0 - 1e-9 && ess <= 301.0,
            "{label}: ess {ess} out of range"
        );
    }
}

/// The cross-stream aggregation rule: ESS-weighted parallel-Welford
/// combine must equal the direct pooled computation over the weighted
/// union, and fold associatively (left fold == right fold == oracle) —
/// the property the coordinator's `query` aggregation rests on.
#[test]
fn welford_merge_is_associative_and_matches_direct_pooling() {
    Runner::new("welford merge associativity", 0xA66).run(120, |g| {
        let d = g.usize_range(1, 3);
        let k = g.usize_range(2, 6);
        // Random per-group (ess, mean, var) snapshots.
        let snaps: Vec<StatSnapshot> = (0..k)
            .map(|j| {
                let ess = g.f64_range(0.5, 40.0);
                let mean: Vec<f64> = (0..d).map(|_| g.f64_range(-5.0, 5.0)).collect();
                let var: Vec<f64> = (0..d).map(|_| g.f64_range(0.0, 4.0)).collect();
                StatSnapshot::from_moments(
                    Arc::from(format!("s{j}").as_str()),
                    10,
                    10.0,
                    ess,
                    mean,
                    var,
                    DEFAULT_Z,
                )
            })
            .collect();
        // Direct pooled oracle over the weighted union.
        let w_total: f64 = snaps.iter().map(|s| s.ess).sum();
        let mut want_mean = vec![0.0; d];
        let mut want_var = vec![0.0; d];
        for i in 0..d {
            want_mean[i] =
                snaps.iter().map(|s| s.ess * s.mean[i]).sum::<f64>() / w_total;
            want_var[i] = snaps
                .iter()
                .map(|s| {
                    s.ess
                        * (s.variance[i]
                            + (s.mean[i] - want_mean[i]) * (s.mean[i] - want_mean[i]))
                })
                .sum::<f64>()
                / w_total;
        }
        // Left fold, right fold, and the aggregate() helper.
        let left = snaps
            .iter()
            .skip(1)
            .fold(snaps[0].clone(), |acc, s| {
                analytics::merge_snapshots(&acc, s, DEFAULT_Z)
            });
        let right = snaps
            .iter()
            .rev()
            .skip(1)
            .fold(snaps[k - 1].clone(), |acc, s| {
                analytics::merge_snapshots(s, &acc, DEFAULT_Z)
            });
        let (agg, pooled) = analytics::aggregate(&snaps, DEFAULT_Z);
        let agg = agg.ok_or("aggregate missing")?;
        if pooled != k {
            return Err(format!("pooled {pooled} of {k}"));
        }
        for m in [&left, &right, &agg] {
            ata::testkit::assert_close(m.ess, w_total, 1e-9, "ess")?;
            for i in 0..d {
                ata::testkit::assert_close(m.mean[i], want_mean[i], 1e-9, "mean")?;
                ata::testkit::assert_close(m.variance[i], want_var[i], 1e-9, "var")?;
            }
        }
        Ok(())
    });
}

/// Banked rows must stream the identical moments as their boxed slot
/// twins (1e-12) — the bank-vs-slot equivalence, extended to the
/// analytics read.
#[test]
fn banked_moments_match_slot_moments() {
    use ata::averagers::banked::{build_bank, RowBatch};
    let bankable = [
        AveragerSpec::Exp { gamma: 0.9 },
        AveragerSpec::ExpK { k: 10 },
        AveragerSpec::Gea { c: 0.5 },
        AveragerSpec::Awa {
            window: WindowKind::Fixed { k: 7 },
            accumulators: 2,
        },
        AveragerSpec::Awa {
            window: WindowKind::Growing { c: 0.5 },
            accumulators: 3,
        },
    ];
    let d = 3usize;
    for spec in bankable {
        let label = spec.label();
        let mut bank = build_bank(&spec, d).expect("bankable");
        let row = bank.push_row();
        let mut slot = spec.build(d).unwrap();
        let mut pos = 0u64;
        for &n in &[1usize, 6, 13, 40, 2] {
            let mut flat = Vec::with_capacity(n * d);
            for s in 0..n {
                for i in 0..d {
                    flat.push(sample(pos + s as u64 + 1, i));
                }
            }
            pos += n as u64;
            bank.apply_batches(&[RowBatch {
                row,
                count: n,
                data: &flat,
            }]);
            slot.observe_many(&flat, n);
            let (mut bm, mut bv) = (vec![0.0; d], vec![0.0; d]);
            let (mut sm, mut sv) = (vec![0.0; d], vec![0.0; d]);
            let be = bank.moments_row_into(row, &mut bm, &mut bv).expect("bank");
            let se = slot.moments_into(&mut sm, &mut sv).expect("slot");
            close(be, se, 1e-12, &format!("{label} ess at t={pos}"));
            for i in 0..d {
                close(bm[i], sm[i], 1e-12, &format!("{label} mean[{i}]"));
                close(bv[i], sv[i], 1e-12, &format!("{label} var[{i}]"));
            }
        }
    }
}

/// End-to-end through the coordinator: stat snapshots survive the
/// export→restore round trip bitwise, on both backings.
#[test]
fn stat_snapshots_survive_state_transfer_bitwise() {
    use ata::config::BackpressurePolicy;
    use ata::coordinator::Coordinator;
    let d = 2;
    let a = Coordinator::new(2, 64, BackpressurePolicy::Block);
    let b = Coordinator::new(1, 64, BackpressurePolicy::Block);
    for (i, spec) in all_specs().into_iter().enumerate() {
        let name = format!("s{i}");
        a.register(&name, d, spec.clone()).unwrap();
        b.register(&name, d, spec).unwrap();
        let mut flat = Vec::new();
        for t in 1..=33u64 {
            for k in 0..d {
                flat.push(sample(t + i as u64, k));
            }
        }
        a.push_many(&name, 33, &flat).unwrap();
    }
    a.sync().unwrap();
    for i in 0..all_specs().len() {
        let name = format!("s{i}");
        let state = a.export_state(&name).unwrap();
        b.restore_state(&name, &state).unwrap();
        let sa = a.stat_snapshot(&name).unwrap();
        let sb = b.stat_snapshot(&name).unwrap();
        assert_eq!(sa.t, sb.t, "{name}");
        assert_eq!(sa.ess.to_bits(), sb.ess.to_bits(), "{name} ess");
        for k in 0..d {
            assert_eq!(sa.mean[k].to_bits(), sb.mean[k].to_bits(), "{name} mean");
            assert_eq!(
                sa.variance[k].to_bits(),
                sb.variance[k].to_bits(),
                "{name} variance"
            );
        }
    }
}

/// Regression (merge path bugfix): merging a never-pushed stream's
/// snapshot into a populated pool must be an exact identity — no panic
/// on the zero-length moment columns an unregistered-dim snapshot
/// carries, no NaN variance, no degenerate (zero-width) band — in both
/// argument orders, and `aggregate` must skip such inputs entirely.
/// Before the fix, the dim assertion ran ahead of the empty-side
/// guards (dim-0 empty snapshot → panic) and a NaN-moment side with
/// positive ESS reached the combine arithmetic (pool → NaN).
#[test]
fn merging_empty_or_degenerate_snapshots_is_identity() {
    // A genuinely populated pool from streamed data.
    let d = 2usize;
    let mut avg = AveragerSpec::Gea { c: 0.5 }.build(d).unwrap();
    for t in 1..=80u64 {
        avg.observe(&[sample(t, 0), sample(t, 1)]);
    }
    let (mut mean, mut var) = (vec![0.0; d], vec![0.0; d]);
    let ess = avg.moments_into(&mut mean, &mut var).expect("moments");
    let pool = StatSnapshot::from_moments(
        Arc::from("pool"),
        80,
        ess,
        ess,
        mean,
        var,
        DEFAULT_Z,
    );
    assert!(pool.is_poolable());
    assert!(pool.confidence_band.iter().all(|&b| b > 0.0));

    // The degenerate inputs the serving path can produce or receive: a
    // never-pushed stream (zero ESS, dim-matched zeros), the same with
    // zero-length moment columns (snapshot taken before any dim was
    // known), and corrupt federation payloads (NaN ESS / NaN variance
    // with a positive ESS).
    let empty_zeroed = StatSnapshot::from_moments(
        Arc::from("never-pushed"),
        0,
        0.0,
        0.0,
        vec![0.0; d],
        vec![0.0; d],
        DEFAULT_Z,
    );
    let empty_dimless = StatSnapshot::from_moments(
        Arc::from("never-pushed-dim0"),
        0,
        0.0,
        0.0,
        Vec::new(),
        Vec::new(),
        DEFAULT_Z,
    );
    let nan_ess = StatSnapshot::from_moments(
        Arc::from("corrupt-ess"),
        5,
        5.0,
        f64::NAN,
        vec![1.0; d],
        vec![1.0; d],
        DEFAULT_Z,
    );
    let nan_var = StatSnapshot::from_moments(
        Arc::from("corrupt-var"),
        5,
        5.0,
        5.0,
        vec![1.0; d],
        vec![f64::NAN; d],
        DEFAULT_Z,
    );
    for degenerate in [&empty_zeroed, &empty_dimless, &nan_ess, &nan_var] {
        assert!(!degenerate.is_poolable(), "{}", degenerate.stream);
        for merged in [
            analytics::merge_snapshots(&pool, degenerate, DEFAULT_Z),
            analytics::merge_snapshots(degenerate, &pool, DEFAULT_Z),
        ] {
            assert_eq!(
                merged.ess.to_bits(),
                pool.ess.to_bits(),
                "{}: identity ess",
                degenerate.stream
            );
            for i in 0..d {
                assert_eq!(
                    merged.mean[i].to_bits(),
                    pool.mean[i].to_bits(),
                    "{}: identity mean[{i}]",
                    degenerate.stream
                );
                assert!(
                    merged.variance[i].is_finite(),
                    "{}: variance[{i}] = {}",
                    degenerate.stream,
                    merged.variance[i]
                );
                assert!(
                    merged.confidence_band[i] > 0.0,
                    "{}: band[{i}] collapsed to {}",
                    degenerate.stream,
                    merged.confidence_band[i]
                );
            }
        }
        // aggregate() skips it and reports only the real pool member.
        let (agg, pooled) =
            analytics::aggregate(&[pool.clone(), (*degenerate).clone()], DEFAULT_Z);
        let agg = agg.expect("aggregate");
        assert_eq!(pooled, 1, "{}", degenerate.stream);
        assert!(agg.variance.iter().all(|v| v.is_finite()));
    }
}

/// The federation router's merge contract: pooling per-node partial
/// aggregates (scatter-gather over simulated cluster partitions) must
/// equal the flat single-node pool over the union of streams, to
/// 1e-12, for any partition of the streams into nodes and any arrival
/// order — with every estimator family contributing real streamed
/// moments, not synthetic ones.
#[test]
fn aggregate_is_partition_and_permutation_invariant() {
    let d = 2usize;
    // One snapshot per estimator family, from genuinely streamed data.
    let snaps: Vec<StatSnapshot> = all_specs()
        .iter()
        .enumerate()
        .map(|(j, spec)| {
            let n = 40 + 17 * j;
            let mut avg = spec.build(d).unwrap();
            let mut flat = Vec::with_capacity(n * d);
            for t in 1..=n as u64 {
                for i in 0..d {
                    flat.push(sample(t, i) + j as f64 * 0.3);
                }
            }
            avg.observe_many(&flat, n);
            let (mut mean, mut var) = (vec![0.0; d], vec![0.0; d]);
            let ess = avg.moments_into(&mut mean, &mut var).expect("moments");
            StatSnapshot::from_moments(
                Arc::from(format!("p{j}").as_str()),
                n as u64,
                ess,
                ess,
                mean,
                var,
                DEFAULT_Z,
            )
        })
        .collect();
    let (flat_agg, flat_n) = analytics::aggregate(&snaps, DEFAULT_Z);
    let flat_agg = flat_agg.expect("flat aggregate");
    assert_eq!(flat_n, snaps.len(), "every family pools");

    Runner::new("N-way partition invariance", 0x9A57).run(60, |g| {
        // A scatter order the router might see...
        let mut perm: Vec<StatSnapshot> = snaps.clone();
        for i in (1..perm.len()).rev() {
            perm.swap(i, g.usize_range(0, i));
        }
        // ...split across 1..=4 simulated nodes.
        let nodes = g.usize_range(1, 4);
        let mut groups: Vec<Vec<StatSnapshot>> = vec![Vec::new(); nodes];
        for s in &perm {
            groups[g.usize_range(0, nodes - 1)].push(s.clone());
        }
        // Per-node partial pools, then the pool of pools.
        let mut partials: Vec<StatSnapshot> = Vec::new();
        for group in groups.iter().filter(|gr| !gr.is_empty()) {
            let (p, pooled) = analytics::aggregate(group, DEFAULT_Z);
            if pooled != group.len() {
                return Err(format!("partial pooled {pooled} of {}", group.len()));
            }
            partials.push(p.ok_or("partial aggregate missing")?);
        }
        let (two_level, _) = analytics::aggregate(&partials, DEFAULT_Z);
        let two_level = two_level.ok_or("two-level aggregate missing")?;
        // And the permuted one-level pool.
        let (permuted, _) = analytics::aggregate(&perm, DEFAULT_Z);
        let permuted = permuted.ok_or("permuted aggregate missing")?;
        for (m, what) in [(&two_level, "two-level"), (&permuted, "permuted")] {
            ata::testkit::assert_close(m.ess, flat_agg.ess, 1e-12, &format!("{what} ess"))?;
            for i in 0..d {
                ata::testkit::assert_close(
                    m.mean[i],
                    flat_agg.mean[i],
                    1e-12,
                    &format!("{what} mean[{i}]"),
                )?;
                ata::testkit::assert_close(
                    m.variance[i],
                    flat_agg.variance[i],
                    1e-12,
                    &format!("{what} var[{i}]"),
                )?;
            }
        }
        Ok(())
    });
}
