//! Cross-language golden test: the Rust averagers must reproduce the
//! python mirror (`python/compile/averagers_ref.py`) bit-for-bit (up to
//! f64 round-off) on a deterministic stream — values AND the moment
//! columns (weighted variance, effective sample size).
//!
//! Regenerate the golden file from either language:
//!   python3 -m compile.averagers_ref ../rust/tests/golden/averager_golden.json
//!   cargo run --example generate_golden

use ata::averagers::AveragerSpec;
use ata::util::json::Json;

const GOLDEN_PATH: &str = "rust/tests/golden/averager_golden.json";

fn stream(t: u64) -> f64 {
    (0.37 * t as f64).sin() * 10.0 + (1.7 * t as f64).cos()
}

fn load_golden() -> Json {
    let text = std::fs::read_to_string(GOLDEN_PATH)
        .unwrap_or_else(|e| {
            panic!(
                "cannot read {GOLDEN_PATH}: {e}; regenerate with \
                 `cargo run --example generate_golden`"
            )
        });
    Json::parse(&text).expect("golden file must be valid JSON")
}

#[test]
fn golden_traces_match_python_mirror() {
    let golden = load_golden();
    let total = golden
        .get("total_steps")
        .and_then(Json::as_u64)
        .expect("total_steps");
    let checkpoints: Vec<u64> = golden
        .get("checkpoints")
        .and_then(Json::as_arr)
        .expect("checkpoints")
        .iter()
        .map(|c| c.as_u64().expect("checkpoint int"))
        .collect();
    let traces = golden
        .get("traces")
        .and_then(Json::as_obj)
        .expect("traces");
    assert!(!traces.is_empty());

    let mut compared = 0usize;
    for (label, trace) in traces {
        let spec = AveragerSpec::parse(label)
            .unwrap_or_else(|e| panic!("golden label '{label}' unparseable: {e}"));
        let mut avg = spec.build(1).expect("build");
        let expected = trace.as_arr().expect("trace array");
        assert_eq!(expected.len(), checkpoints.len(), "{label}");
        let mut cp_idx = 0;
        for t in 1..=total {
            avg.observe_scalar(stream(t));
            if cp_idx < checkpoints.len() && checkpoints[cp_idx] == t {
                let got = avg.value_scalar();
                match (&expected[cp_idx], got) {
                    (Json::Null, None) => {}
                    (Json::Num(want), Some(g)) => {
                        assert!(
                            (g - want).abs() <= 1e-9 * want.abs().max(1.0),
                            "{label} at t={t}: rust {g} vs python {want}"
                        );
                        compared += 1;
                    }
                    (want, got) => {
                        panic!("{label} at t={t}: python {want:?} vs rust {got:?}")
                    }
                }
                cp_idx += 1;
            }
        }
        assert_eq!(cp_idx, checkpoints.len(), "{label}: all checkpoints hit");
    }
    assert!(
        compared > 100,
        "golden comparison too thin: {compared} values"
    );
}

#[test]
fn golden_moment_columns_match_python_mirror() {
    use ata::averagers::Averager;
    let golden = load_golden();
    let total = golden
        .get("total_steps")
        .and_then(Json::as_u64)
        .expect("total_steps");
    let checkpoints: Vec<u64> = golden
        .get("checkpoints")
        .and_then(Json::as_arr)
        .expect("checkpoints")
        .iter()
        .map(|c| c.as_u64().expect("checkpoint int"))
        .collect();
    let moments = golden
        .get("moments")
        .and_then(Json::as_obj)
        .expect("moment traces (regenerate the golden file)");
    assert!(!moments.is_empty());
    let mut compared = 0usize;
    for (label, trace) in moments {
        let spec = AveragerSpec::parse(label)
            .unwrap_or_else(|e| panic!("golden label '{label}' unparseable: {e}"));
        let mut avg: Box<dyn Averager> = spec.build(1).expect("build");
        let expected = trace.as_arr().expect("moment array");
        assert_eq!(expected.len(), checkpoints.len(), "{label}");
        let mut cp_idx = 0;
        for t in 1..=total {
            avg.observe_scalar(stream(t));
            if cp_idx < checkpoints.len() && checkpoints[cp_idx] == t {
                let (mut m, mut v) = ([0.0], [0.0]);
                let got = avg.moments_into(&mut m, &mut v);
                match (&expected[cp_idx], got) {
                    (Json::Null, None) => {}
                    (pair @ Json::Arr(_), Some(ess)) => {
                        let cols = pair.to_f64_vec().expect("[var, ess]");
                        assert_eq!(cols.len(), 2, "{label}");
                        let (want_var, want_ess) = (cols[0], cols[1]);
                        assert!(
                            (v[0] - want_var).abs() <= 1e-9 * want_var.abs().max(1.0),
                            "{label} at t={t}: rust var {} vs python {want_var}",
                            v[0]
                        );
                        assert!(
                            (ess - want_ess).abs() <= 1e-9 * want_ess.max(1.0),
                            "{label} at t={t}: rust ess {ess} vs python {want_ess}"
                        );
                        // The moment mean must be the traced value.
                        let val = avg.value_scalar().expect("value");
                        assert!((m[0] - val).abs() <= 1e-12 * val.abs().max(1.0));
                        compared += 1;
                    }
                    (want, got) => {
                        panic!("{label} at t={t}: python {want:?} vs rust {got:?}")
                    }
                }
                cp_idx += 1;
            }
        }
    }
    assert!(compared > 100, "moment comparison too thin: {compared}");
}

#[test]
fn golden_covers_every_estimator_family() {
    let golden = load_golden();
    let traces = golden.get("traces").and_then(Json::as_obj).unwrap();
    let labels: Vec<&str> = traces.keys().map(String::as_str).collect();
    for family in ["expk", "gea", "awa2", "awa3", "true", "raw", "restart", "twotail"] {
        assert!(
            labels.iter().any(|l| l.starts_with(family)),
            "golden file missing family '{family}' (have {labels:?})"
        );
    }
}
