//! Property tests (via the in-crate testkit) of the paper's invariants,
//! over randomized specs, dimensions and stream lengths.

use ata::averagers::{
    reconstruct_weights, report_from_weights, Averager, AveragerSpec, WindowKind,
};
use ata::testkit::{assert_close, assert_slice_close, Gen, Runner};

/// Draw a random estimator spec (all families).
fn arb_spec(g: &mut Gen, total_steps: u64) -> AveragerSpec {
    match g.usize_range(0, 7) {
        0 => AveragerSpec::ExpK {
            k: g.usize_range(1, 40) as u64,
        },
        1 => AveragerSpec::Gea {
            c: g.f64_range(0.05, 0.95),
        },
        2 => AveragerSpec::Awa {
            window: arb_window(g),
            accumulators: g.usize_range(2, 5) as u32,
        },
        3 => AveragerSpec::True {
            window: arb_window(g),
        },
        4 => AveragerSpec::Raw {
            c: g.f64_range(0.1, 0.9),
            total_steps,
        },
        5 => AveragerSpec::Restart {
            window: arb_window(g),
        },
        6 => AveragerSpec::Eh {
            window: arb_window(g),
            eps: g.f64_range(0.02, 0.3),
        },
        _ => AveragerSpec::Exp {
            gamma: g.f64_range(0.0, 0.99),
        },
    }
}

fn arb_window(g: &mut Gen) -> WindowKind {
    if g.bool(0.5) {
        WindowKind::Fixed {
            k: g.usize_range(1, 30) as u64,
        }
    } else {
        WindowKind::Growing {
            c: g.f64_range(0.05, 0.95),
        }
    }
}

#[test]
fn weights_always_sum_to_one() {
    Runner::new("Σα = 1 for every estimator/time", 0xA11).run(60, |g| {
        let t = g.usize_range(1, 60) as u64;
        let spec = arb_spec(g, t.max(4));
        let w = reconstruct_weights(&spec, t).map_err(|e| e.to_string())?;
        let sum: f64 = w.iter().sum();
        assert_close(sum, 1.0, 1e-9, &format!("{} t={t}", spec.label()))
    });
}

#[test]
fn no_estimator_uses_negative_weights() {
    Runner::new("α ≥ 0", 0xA12).run(40, |g| {
        let t = g.usize_range(1, 50) as u64;
        let spec = arb_spec(g, t.max(4));
        let w = reconstruct_weights(&spec, t).map_err(|e| e.to_string())?;
        for (i, &wi) in w.iter().enumerate() {
            if wi < -1e-12 {
                return Err(format!("{} t={t}: α[{i}]={wi}", spec.label()));
            }
        }
        Ok(())
    });
}

#[test]
fn variance_never_beats_window_target_materially() {
    // Σα² ≥ 1/t always, and for the anytime estimators Σα² ≤ ~1/k_t once
    // enough samples exist (they never *exceed* the exact-window variance
    // by more than round-off, i.e. are never noisier than promised).
    Runner::new("variance bounded by design", 0xA13).run(40, |g| {
        let t = g.usize_range(2, 60) as u64;
        let c = g.f64_range(0.2, 0.8);
        let accs = g.usize_range(1, 3) as u32 + 1;
        let spec = AveragerSpec::Awa {
            window: WindowKind::Growing { c },
            accumulators: accs,
        };
        let w = reconstruct_weights(&spec, t).map_err(|e| e.to_string())?;
        let var: f64 = w.iter().map(|a| a * a).sum();
        let k_t = (c * t as f64).max(1.0).min(t as f64);
        // Attainable once pooled samples ≥ k_t — always true for AWA after
        // t ≥ 2 because it can use up to all t samples.
        if var > 1.0 / k_t + 1e-9 {
            return Err(format!(
                "awa{accs}(c={c}) t={t}: Σα²={var} exceeds 1/k_t={}",
                1.0 / k_t
            ));
        }
        if var < 1.0 / t as f64 - 1e-12 {
            return Err(format!("impossible variance {var} < 1/t"));
        }
        Ok(())
    });
}

#[test]
fn awa_support_is_bounded_unlike_ema() {
    // AWA's oldest used sample is at most (z+1 chunks) old; EMA touches
    // everything. Quantify on random fixed-k configs.
    Runner::new("AWA bounded staleness", 0xA14).run(30, |g| {
        let k = g.usize_range(2, 20) as u64;
        let t = (3 * k + g.usize_range(0, 20) as u64).max(2 * k + 1);
        let awa = AveragerSpec::Awa {
            window: WindowKind::Fixed { k },
            accumulators: 2,
        };
        let w = reconstruct_weights(&awa, t).map_err(|e| e.to_string())?;
        let r = report_from_weights(&w, t, k as f64);
        if r.max_age > 2 * k {
            return Err(format!("awa2(k={k}) t={t}: max_age {} > 2k", r.max_age));
        }
        Ok(())
    });
}

#[test]
fn estimators_are_translation_equivariant() {
    // Averaging x+c must equal averaging x, plus c — linearity plus
    // Σα = 1 in operational form, on the actual estimator (not the
    // reconstruction).
    Runner::new("translation equivariance", 0xA15).run(40, |g| {
        let t = g.usize_range(1, 80) as u64;
        let spec = arb_spec(g, t.max(4));
        let shift = g.f64_range(-100.0, 100.0);
        let mut a = spec.build(1).map_err(|e| e)?;
        let mut b = spec.build(1).map_err(|e| e)?;
        let mut xs = Vec::new();
        for i in 0..t {
            let x = g.gaussian() * 5.0 + (i as f64 * 0.3).sin();
            xs.push(x);
            a.observe_scalar(x);
            b.observe_scalar(x + shift);
        }
        match (a.value_scalar(), b.value_scalar()) {
            (Some(va), Some(vb)) => assert_close(
                vb,
                va + shift,
                1e-9,
                &format!("{} t={t}", spec.label()),
            ),
            (None, None) => Ok(()),
            _ => Err("availability must not depend on shift".to_string()),
        }
    });
}

#[test]
fn estimators_are_scale_equivariant() {
    Runner::new("scale equivariance", 0xA16).run(40, |g| {
        let t = g.usize_range(1, 80) as u64;
        let spec = arb_spec(g, t.max(4));
        let scale = g.f64_range(0.1, 50.0);
        let mut a = spec.build(1)?;
        let mut b = spec.build(1)?;
        for i in 0..t {
            let x = g.gaussian() + (i as f64 * 0.7).cos();
            a.observe_scalar(x);
            b.observe_scalar(x * scale);
        }
        match (a.value_scalar(), b.value_scalar()) {
            (Some(va), Some(vb)) => assert_close(
                vb,
                va * scale,
                1e-9,
                &format!("{} t={t}", spec.label()),
            ),
            (None, None) => Ok(()),
            _ => Err("availability must not depend on scale".to_string()),
        }
    });
}

#[test]
fn vector_estimators_process_coordinates_independently() {
    Runner::new("coordinatewise independence", 0xA17).run(25, |g| {
        let t = g.usize_range(1, 50) as u64;
        let d = g.usize_range(2, 6);
        let spec = arb_spec(g, t.max(4));
        let mut vector = spec.build(d)?;
        let mut scalars: Vec<_> = (0..d).map(|_| spec.build(1).unwrap()).collect();
        for _ in 0..t {
            let x: Vec<f64> = (0..d).map(|_| g.gaussian() * 3.0).collect();
            vector.observe(&x);
            for (s, &xv) in scalars.iter_mut().zip(&x) {
                s.observe_scalar(xv);
            }
        }
        let vv = vector.value();
        for (i, s) in scalars.iter().enumerate() {
            let sv = s.value_scalar();
            match (&vv, sv) {
                (Some(v), Some(sv)) => {
                    assert_close(v[i], sv, 1e-12, &format!("{} dim {i}", spec.label()))?
                }
                (None, None) => {}
                _ => return Err("availability mismatch".to_string()),
            }
        }
        Ok(())
    });
}

#[test]
fn anytime_estimators_keep_constant_memory() {
    Runner::new("O(1) memory for anytime estimators", 0xA18).run(20, |g| {
        let spec = match g.usize_range(0, 3) {
            0 => AveragerSpec::ExpK {
                k: g.usize_range(1, 50) as u64,
            },
            1 => AveragerSpec::Gea {
                c: g.f64_range(0.1, 0.9),
            },
            _ => AveragerSpec::Awa {
                window: WindowKind::Growing {
                    c: g.f64_range(0.1, 0.9),
                },
                accumulators: g.usize_range(2, 6) as u32,
            },
        };
        let d = g.usize_range(1, 8);
        let mut a = spec.build(d)?;
        let x = vec![1.0; d];
        a.observe(&x);
        let m0 = a.memory_floats();
        for _ in 0..2000 {
            a.observe(&x);
        }
        if a.memory_floats() != m0 {
            return Err(format!(
                "{}: memory changed {m0} → {}",
                spec.label(),
                a.memory_floats()
            ));
        }
        Ok(())
    });
}

#[test]
fn observe_many_over_random_splits_equals_sequential_observe() {
    // THE batched-ingest contract: for every estimator family, feeding a
    // stream through `observe_many` in arbitrary batch splits must agree
    // elementwise (≤ 1e-12, relative to scale) with one-at-a-time
    // `observe` — at every batch boundary, across `reset()`, and with
    // mixed batch sizes. Everything except the EMA's closed-form γⁿ fold
    // is bit-identical by construction; the tolerance covers that fold.
    Runner::new("observe_many ≡ observe over random splits", 0xB17).run(60, |g| {
        let spec = arb_spec(g, 240);
        let d = g.usize_range(1, 4);
        let mut seq = spec.build(d)?;
        let mut bat = spec.build(d)?;
        let mut out_seq = vec![0.0; d];
        let mut out_bat = vec![0.0; d];
        for phase in 0..2 {
            let total = g.usize_range(1, 120);
            let mut fed = 0usize;
            while fed < total {
                let count = g.usize_range(1, (total - fed).min(48));
                let flat: Vec<f64> = (0..count * d).map(|_| g.gaussian() * 2.0).collect();
                for x in flat.chunks_exact(d) {
                    seq.observe(x);
                }
                bat.observe_many(&flat, count);
                fed += count;
                let ctx = format!(
                    "{} d={d} phase={phase} t={} batch={count}",
                    spec.label(),
                    seq.t()
                );
                if seq.t() != bat.t() {
                    return Err(format!("{ctx}: t {} vs {}", seq.t(), bat.t()));
                }
                if (seq.window_len() - bat.window_len()).abs() > 1e-12 {
                    return Err(format!(
                        "{ctx}: window_len {} vs {}",
                        seq.window_len(),
                        bat.window_len()
                    ));
                }
                let (have_seq, have_bat) =
                    (seq.value_into(&mut out_seq), bat.value_into(&mut out_bat));
                if have_seq != have_bat {
                    return Err(format!("{ctx}: availability {have_seq} vs {have_bat}"));
                }
                if have_seq {
                    assert_slice_close(&out_bat, &out_seq, 1e-12, &ctx)?;
                }
            }
            // Equivalence must survive estimator reuse.
            seq.reset();
            bat.reset();
        }
        Ok(())
    });
}

/// Deterministic stream for the merge/oracle tests below.
fn sample(t: u64, i: usize) -> f64 {
    ((t as f64) * 0.379 + (i as f64) * 1.1).sin() * 3.0 + ((t as f64) * 0.05).cos()
}

fn export_bytes(a: &dyn Averager) -> Vec<u8> {
    let mut enc = ata::persist::codec::Enc::new();
    a.export_state(&mut enc);
    enc.into_bytes()
}

/// `merge_state` must be deterministic regardless of argument order,
/// and its returned [`MergeOutcome`] must name the winner explicitly.
/// Poolers (exp/expk/gea/awa*/raw) absorb both sides' mass — the pooled
/// value agrees across argument order to 1e-12 (floating-point pooling
/// commutes only up to round-off). Precedence families (true/restart/
/// eh/twotail) keep exactly one side — the surviving state must be
/// BYTE-identical whichever side initiated the merge, including the
/// equal-`t` tie, which resolves by canonical payload order rather than
/// by who called whom.
#[test]
fn merge_is_order_independent_and_reports_the_winner() {
    use ata::averagers::MergeOutcome;
    use ata::persist::codec::Dec;
    let specs: Vec<(AveragerSpec, bool)> = vec![
        (AveragerSpec::Exp { gamma: 0.9 }, true),
        (AveragerSpec::ExpK { k: 10 }, true),
        (AveragerSpec::Gea { c: 0.5 }, true),
        (
            AveragerSpec::Awa {
                window: WindowKind::Fixed { k: 7 },
                accumulators: 2,
            },
            true,
        ),
        (
            AveragerSpec::Awa {
                window: WindowKind::Growing { c: 0.4 },
                accumulators: 3,
            },
            true,
        ),
        (
            AveragerSpec::Raw {
                c: 0.5,
                total_steps: 200,
            },
            true,
        ),
        (
            AveragerSpec::True {
                window: WindowKind::Fixed { k: 11 },
            },
            false,
        ),
        (
            AveragerSpec::True {
                window: WindowKind::Growing { c: 0.5 },
            },
            false,
        ),
        (
            AveragerSpec::Restart {
                window: WindowKind::Fixed { k: 7 },
            },
            false,
        ),
        (
            AveragerSpec::Eh {
                window: WindowKind::Fixed { k: 40 },
                eps: 0.1,
            },
            false,
        ),
        (AveragerSpec::TwoTail { r: 0.5 }, false),
    ];
    let d = 2usize;
    for (spec, pools) in specs {
        let label = spec.label();
        for (la, lb) in [(120u64, 180u64), (180, 120), (150, 150)] {
            let ctx = format!("{label} la={la} lb={lb}");
            // Two genuinely different streams.
            let mut a = spec.build(d).unwrap();
            let mut b = spec.build(d).unwrap();
            for t in 1..=la {
                a.observe(&[sample(t, 0), sample(t, 1)]);
            }
            for t in 1..=lb {
                b.observe(&[sample(t + 17, 0) + 0.5, sample(t + 17, 1) - 0.25]);
            }
            let (bytes_a, bytes_b) = (export_bytes(&*a), export_bytes(&*b));

            // Merge in both argument orders.
            let mut ab = spec.build(d).unwrap();
            ab.import_state(&mut Dec::new(&bytes_a)).unwrap();
            let out_ab = ab.merge_state(&mut Dec::new(&bytes_b)).unwrap();
            let mut ba = spec.build(d).unwrap();
            ba.import_state(&mut Dec::new(&bytes_b)).unwrap();
            let out_ba = ba.merge_state(&mut Dec::new(&bytes_a)).unwrap();

            assert_eq!(ab.t(), ba.t(), "{ctx}: merged t");
            if pools {
                assert_eq!(out_ab, MergeOutcome::Pooled, "{ctx}");
                assert_eq!(out_ba, MergeOutcome::Pooled, "{ctx}");
                let (va, vb) = (ab.value().unwrap(), ba.value().unwrap());
                assert_slice_close(&va, &vb, 1e-12, &ctx).unwrap();
            } else {
                assert_eq!(
                    export_bytes(&*ab),
                    export_bytes(&*ba),
                    "{ctx}: precedence merge depends on argument order"
                );
                match (out_ab, out_ba) {
                    // One side won; both orders agree on which.
                    (MergeOutcome::TookPeer, MergeOutcome::KeptSelf)
                    | (MergeOutcome::KeptSelf, MergeOutcome::TookPeer) => {}
                    other => panic!("{ctx}: inconsistent winner flags {other:?}"),
                }
                // Longer stream always wins; only the equal-t tie falls
                // through to the payload-order tie-break.
                if la > lb {
                    assert_eq!(out_ab, MergeOutcome::KeptSelf, "{ctx}");
                } else if lb > la {
                    assert_eq!(out_ab, MergeOutcome::TookPeer, "{ctx}");
                }
            }
        }
    }
}

/// The two-tail switching rule against a brute-force oracle. Two
/// claims, on synthetic drifting streams with KNOWN mean and noise:
///
/// 1. **Exactness**: the reported value is exactly the uniform mean of
///    the last `selected_window()` samples — the estimator only ever
///    *selects* a suffix, it never distorts it.
/// 2. **Suboptimality**: the selected window's true squared error
///    (known bias² + σ²/n) is within a constant factor of the best
///    achievable over ALL suffix lengths, plus the rule's intrinsic
///    bias-detection floor ~σ⁴/Δ² (a drift smaller than the noise on
///    the tails' error estimates is invisible by design — the paper's
///    var/ESS proxy, not a defect).
///
/// The claim is the paper's "once-in-a-while" optimality: maturity
/// checks for ratio `r` are geometrically spaced (factor `1/(1−r)`), so
/// a shift landing just after a late check stays legitimately invisible
/// until the NEXT check. The test therefore places the shift early
/// (first sixth) and runs long enough that every probed ratio gets
/// post-shift checks before the horizon; r=0.75 (×4 check spacing) is
/// probed only on stationary streams, where the claim is that the rule
/// must NOT collapse the window. Bound constants empirically hold with
/// ~10× margin over 15k randomized streams.
#[test]
fn two_tail_switching_rule_tracks_brute_force_oracle() {
    use ata::averagers::TwoTail;
    Runner::new("two-tail vs brute-force oracle", 0x77A1).run(20, |g| {
        let total = g.usize_range(1200, 2000);
        let sigma = g.f64_range(0.2, 1.0);
        // Level shift of 4σ..12σ in the first sixth, or a stationary
        // stream (the rule must NOT collapse the window).
        let shifted = g.bool(0.7);
        let r = if shifted {
            [0.25, 0.5][g.usize_range(0, 1)]
        } else {
            [0.25, 0.5, 0.75][g.usize_range(0, 2)]
        };
        let s = g.usize_range(total / 8, total / 6);
        let delta = g.f64_range(4.0, 12.0) * sigma;
        let mut avg = TwoTail::new(1, r)?;
        let mut xs: Vec<f64> = Vec::with_capacity(total);
        for i in 1..=total {
            let mu = if shifted && i > s { delta } else { 0.0 };
            let x = mu + g.gaussian() * sigma;
            xs.push(x);
            avg.observe_scalar(x);
        }
        let ctx = format!(
            "r={r} σ={sigma:.2} total={total} shift={}",
            if shifted { format!("{delta:.2}@{s}") } else { "none".into() }
        );

        // 1. Exactness: value == mean of the last W raw samples.
        let w = avg.selected_window() as usize;
        if w == 0 || w > total {
            return Err(format!("{ctx}: selected window {w} out of range"));
        }
        let direct: f64 = xs[total - w..].iter().sum::<f64>() / w as f64;
        assert_close(
            avg.value_scalar().unwrap(),
            direct,
            1e-9,
            &format!("{ctx}: value vs brute-force suffix mean (W={w})"),
        )?;

        // 2. Suboptimality vs the best suffix, by the KNOWN moments.
        let post = if shifted { total - s } else { total };
        let err_of = |n: usize| -> f64 {
            let bias = if n <= post {
                0.0
            } else {
                delta * (n - post) as f64 / n as f64
            };
            bias * bias + sigma * sigma / n as f64
        };
        let best = (1..=total).map(err_of).fold(f64::INFINITY, f64::min);
        let got = err_of(w);
        let floor = if shifted {
            4.0 * sigma.powi(4) / (delta * delta)
        } else {
            0.0
        };
        if got > 12.0 * best + floor + 1e-9 {
            return Err(format!(
                "{ctx}: selected W={w} err {got:.6} vs best {best:.6} (floor {floor:.6})"
            ));
        }
        Ok(())
    });
}

#[test]
fn gea_effective_window_converges_for_random_c() {
    Runner::new("GEA k_eff/t → c", 0xA19).run(15, |g| {
        let c = g.f64_range(0.05, 0.95);
        let mut a = ata::averagers::GrowingExp::new(1, c)?;
        for _ in 0..30_000 {
            a.observe_scalar(g.gaussian());
        }
        let ratio = a.effective_window() / a.t() as f64;
        assert_close(ratio, c, 1e-4, &format!("c={c}"))
    });
}
