//! Property tests (via the in-crate testkit) of the paper's invariants,
//! over randomized specs, dimensions and stream lengths.

use ata::averagers::{
    reconstruct_weights, report_from_weights, Averager, AveragerSpec, WindowKind,
};
use ata::testkit::{assert_close, assert_slice_close, Gen, Runner};

/// Draw a random estimator spec (all families).
fn arb_spec(g: &mut Gen, total_steps: u64) -> AveragerSpec {
    match g.usize_range(0, 7) {
        0 => AveragerSpec::ExpK {
            k: g.usize_range(1, 40) as u64,
        },
        1 => AveragerSpec::Gea {
            c: g.f64_range(0.05, 0.95),
        },
        2 => AveragerSpec::Awa {
            window: arb_window(g),
            accumulators: g.usize_range(2, 5) as u32,
        },
        3 => AveragerSpec::True {
            window: arb_window(g),
        },
        4 => AveragerSpec::Raw {
            c: g.f64_range(0.1, 0.9),
            total_steps,
        },
        5 => AveragerSpec::Restart {
            window: arb_window(g),
        },
        6 => AveragerSpec::Eh {
            window: arb_window(g),
            eps: g.f64_range(0.02, 0.3),
        },
        _ => AveragerSpec::Exp {
            gamma: g.f64_range(0.0, 0.99),
        },
    }
}

fn arb_window(g: &mut Gen) -> WindowKind {
    if g.bool(0.5) {
        WindowKind::Fixed {
            k: g.usize_range(1, 30) as u64,
        }
    } else {
        WindowKind::Growing {
            c: g.f64_range(0.05, 0.95),
        }
    }
}

#[test]
fn weights_always_sum_to_one() {
    Runner::new("Σα = 1 for every estimator/time", 0xA11).run(60, |g| {
        let t = g.usize_range(1, 60) as u64;
        let spec = arb_spec(g, t.max(4));
        let w = reconstruct_weights(&spec, t).map_err(|e| e.to_string())?;
        let sum: f64 = w.iter().sum();
        assert_close(sum, 1.0, 1e-9, &format!("{} t={t}", spec.label()))
    });
}

#[test]
fn no_estimator_uses_negative_weights() {
    Runner::new("α ≥ 0", 0xA12).run(40, |g| {
        let t = g.usize_range(1, 50) as u64;
        let spec = arb_spec(g, t.max(4));
        let w = reconstruct_weights(&spec, t).map_err(|e| e.to_string())?;
        for (i, &wi) in w.iter().enumerate() {
            if wi < -1e-12 {
                return Err(format!("{} t={t}: α[{i}]={wi}", spec.label()));
            }
        }
        Ok(())
    });
}

#[test]
fn variance_never_beats_window_target_materially() {
    // Σα² ≥ 1/t always, and for the anytime estimators Σα² ≤ ~1/k_t once
    // enough samples exist (they never *exceed* the exact-window variance
    // by more than round-off, i.e. are never noisier than promised).
    Runner::new("variance bounded by design", 0xA13).run(40, |g| {
        let t = g.usize_range(2, 60) as u64;
        let c = g.f64_range(0.2, 0.8);
        let accs = g.usize_range(1, 3) as u32 + 1;
        let spec = AveragerSpec::Awa {
            window: WindowKind::Growing { c },
            accumulators: accs,
        };
        let w = reconstruct_weights(&spec, t).map_err(|e| e.to_string())?;
        let var: f64 = w.iter().map(|a| a * a).sum();
        let k_t = (c * t as f64).max(1.0).min(t as f64);
        // Attainable once pooled samples ≥ k_t — always true for AWA after
        // t ≥ 2 because it can use up to all t samples.
        if var > 1.0 / k_t + 1e-9 {
            return Err(format!(
                "awa{accs}(c={c}) t={t}: Σα²={var} exceeds 1/k_t={}",
                1.0 / k_t
            ));
        }
        if var < 1.0 / t as f64 - 1e-12 {
            return Err(format!("impossible variance {var} < 1/t"));
        }
        Ok(())
    });
}

#[test]
fn awa_support_is_bounded_unlike_ema() {
    // AWA's oldest used sample is at most (z+1 chunks) old; EMA touches
    // everything. Quantify on random fixed-k configs.
    Runner::new("AWA bounded staleness", 0xA14).run(30, |g| {
        let k = g.usize_range(2, 20) as u64;
        let t = (3 * k + g.usize_range(0, 20) as u64).max(2 * k + 1);
        let awa = AveragerSpec::Awa {
            window: WindowKind::Fixed { k },
            accumulators: 2,
        };
        let w = reconstruct_weights(&awa, t).map_err(|e| e.to_string())?;
        let r = report_from_weights(&w, t, k as f64);
        if r.max_age > 2 * k {
            return Err(format!("awa2(k={k}) t={t}: max_age {} > 2k", r.max_age));
        }
        Ok(())
    });
}

#[test]
fn estimators_are_translation_equivariant() {
    // Averaging x+c must equal averaging x, plus c — linearity plus
    // Σα = 1 in operational form, on the actual estimator (not the
    // reconstruction).
    Runner::new("translation equivariance", 0xA15).run(40, |g| {
        let t = g.usize_range(1, 80) as u64;
        let spec = arb_spec(g, t.max(4));
        let shift = g.f64_range(-100.0, 100.0);
        let mut a = spec.build(1).map_err(|e| e)?;
        let mut b = spec.build(1).map_err(|e| e)?;
        let mut xs = Vec::new();
        for i in 0..t {
            let x = g.gaussian() * 5.0 + (i as f64 * 0.3).sin();
            xs.push(x);
            a.observe_scalar(x);
            b.observe_scalar(x + shift);
        }
        match (a.value_scalar(), b.value_scalar()) {
            (Some(va), Some(vb)) => assert_close(
                vb,
                va + shift,
                1e-9,
                &format!("{} t={t}", spec.label()),
            ),
            (None, None) => Ok(()),
            _ => Err("availability must not depend on shift".to_string()),
        }
    });
}

#[test]
fn estimators_are_scale_equivariant() {
    Runner::new("scale equivariance", 0xA16).run(40, |g| {
        let t = g.usize_range(1, 80) as u64;
        let spec = arb_spec(g, t.max(4));
        let scale = g.f64_range(0.1, 50.0);
        let mut a = spec.build(1)?;
        let mut b = spec.build(1)?;
        for i in 0..t {
            let x = g.gaussian() + (i as f64 * 0.7).cos();
            a.observe_scalar(x);
            b.observe_scalar(x * scale);
        }
        match (a.value_scalar(), b.value_scalar()) {
            (Some(va), Some(vb)) => assert_close(
                vb,
                va * scale,
                1e-9,
                &format!("{} t={t}", spec.label()),
            ),
            (None, None) => Ok(()),
            _ => Err("availability must not depend on scale".to_string()),
        }
    });
}

#[test]
fn vector_estimators_process_coordinates_independently() {
    Runner::new("coordinatewise independence", 0xA17).run(25, |g| {
        let t = g.usize_range(1, 50) as u64;
        let d = g.usize_range(2, 6);
        let spec = arb_spec(g, t.max(4));
        let mut vector = spec.build(d)?;
        let mut scalars: Vec<_> = (0..d).map(|_| spec.build(1).unwrap()).collect();
        for _ in 0..t {
            let x: Vec<f64> = (0..d).map(|_| g.gaussian() * 3.0).collect();
            vector.observe(&x);
            for (s, &xv) in scalars.iter_mut().zip(&x) {
                s.observe_scalar(xv);
            }
        }
        let vv = vector.value();
        for (i, s) in scalars.iter().enumerate() {
            let sv = s.value_scalar();
            match (&vv, sv) {
                (Some(v), Some(sv)) => {
                    assert_close(v[i], sv, 1e-12, &format!("{} dim {i}", spec.label()))?
                }
                (None, None) => {}
                _ => return Err("availability mismatch".to_string()),
            }
        }
        Ok(())
    });
}

#[test]
fn anytime_estimators_keep_constant_memory() {
    Runner::new("O(1) memory for anytime estimators", 0xA18).run(20, |g| {
        let spec = match g.usize_range(0, 3) {
            0 => AveragerSpec::ExpK {
                k: g.usize_range(1, 50) as u64,
            },
            1 => AveragerSpec::Gea {
                c: g.f64_range(0.1, 0.9),
            },
            _ => AveragerSpec::Awa {
                window: WindowKind::Growing {
                    c: g.f64_range(0.1, 0.9),
                },
                accumulators: g.usize_range(2, 6) as u32,
            },
        };
        let d = g.usize_range(1, 8);
        let mut a = spec.build(d)?;
        let x = vec![1.0; d];
        a.observe(&x);
        let m0 = a.memory_floats();
        for _ in 0..2000 {
            a.observe(&x);
        }
        if a.memory_floats() != m0 {
            return Err(format!(
                "{}: memory changed {m0} → {}",
                spec.label(),
                a.memory_floats()
            ));
        }
        Ok(())
    });
}

#[test]
fn observe_many_over_random_splits_equals_sequential_observe() {
    // THE batched-ingest contract: for every estimator family, feeding a
    // stream through `observe_many` in arbitrary batch splits must agree
    // elementwise (≤ 1e-12, relative to scale) with one-at-a-time
    // `observe` — at every batch boundary, across `reset()`, and with
    // mixed batch sizes. Everything except the EMA's closed-form γⁿ fold
    // is bit-identical by construction; the tolerance covers that fold.
    Runner::new("observe_many ≡ observe over random splits", 0xB17).run(60, |g| {
        let spec = arb_spec(g, 240);
        let d = g.usize_range(1, 4);
        let mut seq = spec.build(d)?;
        let mut bat = spec.build(d)?;
        let mut out_seq = vec![0.0; d];
        let mut out_bat = vec![0.0; d];
        for phase in 0..2 {
            let total = g.usize_range(1, 120);
            let mut fed = 0usize;
            while fed < total {
                let count = g.usize_range(1, (total - fed).min(48));
                let flat: Vec<f64> = (0..count * d).map(|_| g.gaussian() * 2.0).collect();
                for x in flat.chunks_exact(d) {
                    seq.observe(x);
                }
                bat.observe_many(&flat, count);
                fed += count;
                let ctx = format!(
                    "{} d={d} phase={phase} t={} batch={count}",
                    spec.label(),
                    seq.t()
                );
                if seq.t() != bat.t() {
                    return Err(format!("{ctx}: t {} vs {}", seq.t(), bat.t()));
                }
                if (seq.window_len() - bat.window_len()).abs() > 1e-12 {
                    return Err(format!(
                        "{ctx}: window_len {} vs {}",
                        seq.window_len(),
                        bat.window_len()
                    ));
                }
                let (have_seq, have_bat) =
                    (seq.value_into(&mut out_seq), bat.value_into(&mut out_bat));
                if have_seq != have_bat {
                    return Err(format!("{ctx}: availability {have_seq} vs {have_bat}"));
                }
                if have_seq {
                    assert_slice_close(&out_bat, &out_seq, 1e-12, &ctx)?;
                }
            }
            // Equivalence must survive estimator reuse.
            seq.reset();
            bat.reset();
        }
        Ok(())
    });
}

#[test]
fn gea_effective_window_converges_for_random_c() {
    Runner::new("GEA k_eff/t → c", 0xA19).run(15, |g| {
        let c = g.f64_range(0.05, 0.95);
        let mut a = ata::averagers::GrowingExp::new(1, c)?;
        for _ in 0..30_000 {
            a.observe_scalar(g.gaussian());
        }
        let ratio = a.effective_window() / a.t() as f64;
        assert_close(ratio, c, 1e-4, &format!("c={c}"))
    });
}
