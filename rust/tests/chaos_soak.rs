//! Deterministic chaos soak: the full service stack (durable
//! coordinator → TCP server → retrying client) runs under a seeded
//! fault plan — torn WAL writes, fsync errors and stalls, connection
//! resets, shard-worker panics — and must keep its accounting exact:
//!
//! * every acknowledged sample is either applied to live state or
//!   surfaced in the drop counters (nothing vanishes silently);
//! * recovery loses exactly the torn-away WAL records and nothing else;
//! * recovering the same state directory twice yields bitwise-identical
//!   snapshots.
//!
//! Chaos state is process-global, so every test that arms a plan holds
//! [`chaos::test_mutex`] — which is also why all chaos-driven
//! integration tests live in this one binary.

use ata::config::{BackpressurePolicy, PersistConfig, ServiceConfig};
use ata::coordinator::{
    Client, ClientError, Coordinator, ProtocolChoice, RetryPolicy, RetryingClient, Server,
    ServerOptions,
};
use ata::obs::recorder::EventKind;
use ata::testkit::chaos;
use ata::testkit::temp_dir;
use std::path::Path;
use std::sync::Arc;

/// Streams under chaos get this prefix so worker-panic injection
/// (scoped via `panic_prefix`) can never leak onto another test's
/// streams if more tests join this binary.
const SOAK_PREFIX: &str = "soak/";

fn soak_cfg(dir: &Path, shards: usize, queue: usize, policy: BackpressurePolicy) -> ServiceConfig {
    ServiceConfig {
        shards,
        queue_capacity: queue,
        backpressure: policy,
        // Injected panics must not poison a stream mid-soak — a
        // poisoned stream rejects pushes with a fatal (non-retryable)
        // error and the accounting below assumes every stream stays
        // writable. The poison policy has its own unit test.
        poison_threshold: 1_000_000,
        persist: Some(PersistConfig {
            dir: dir.display().to_string(),
            // Small segments so the soak crosses many rotation
            // boundaries (torn-append healing rotates too).
            segment_bytes: 8 << 10,
            // Real fsyncs so the fsync-error and fsync-stall sites are
            // actually reached; per-append mode (no group commit) keeps
            // shutdown trivially flush-free.
            fsync: true,
            checkpoint_interval_ms: 0,
            group_commit_micros: 0,
        }),
        ..Default::default()
    }
}

/// Deterministic sample: stream `s`, batch `b`, slot `i`.
fn sample(s: usize, b: usize, i: usize) -> f64 {
    ((s as f64) * 1.3 + (b as f64) * 0.17 + (i as f64) * 0.71).sin() * 2.0
}

fn bits(v: &[f64]) -> Vec<u64> {
    v.iter().map(|x| x.to_bits()).collect()
}

fn counter(doc: &ata::util::json::Json, name: &str) -> u64 {
    doc.get(&format!("counter.{name}"))
        .and_then(|v| v.as_u64())
        .unwrap_or(0)
}

/// The soak proper: ~240 fixed-size batches through a retrying client
/// while every fault site fires, then exact accounting + recovery.
#[test]
fn seeded_chaos_soak_keeps_accounting_exact_and_recovers_deterministically() {
    let _guard = chaos::test_mutex().lock().unwrap_or_else(|e| e.into_inner());
    chaos::disarm();
    let dir = temp_dir("chaos-soak");
    let cfg = soak_cfg(&dir, 2, 256, BackpressurePolicy::Block);
    let coordinator = Arc::new(Coordinator::from_config(&cfg).expect("durable coordinator"));
    let server = Server::start_with_options(
        "127.0.0.1:0",
        Arc::clone(&coordinator),
        4,
        ServerOptions::default(),
    )
    .expect("server");
    let addr = server.addr().to_string();

    let streams: Vec<String> = (0..4).map(|s| format!("{SOAK_PREFIX}{s}")).collect();
    let specs = ["gea(c=0.5)", "awa3(c=0.5)", "true(k=9)", "gea(c=0.25)"];
    const DIM: usize = 3;
    const BATCH: usize = 5; // samples per push — fixed, so losses
                            // convert to sample counts exactly.
    const BATCHES: usize = 240;

    let mut rc = RetryingClient::with_policy(
        &addr,
        ProtocolChoice::Auto,
        RetryPolicy {
            max_attempts: 8,
            base_backoff_ms: 1,
            max_backoff_ms: 20,
            seed: 0xDECAF,
        },
    );
    // Register (and make durable) before arming: the WAL register
    // records must survive so recovery re-creates every stream.
    for (s, name) in streams.iter().enumerate() {
        rc.register(name, DIM, specs[s]).expect("register");
    }
    rc.sync().expect("pre-chaos sync");

    chaos::arm(chaos::ChaosPlan {
        seed: 0x50AB_2026,
        torn_write_per_mille: 60,
        fsync_error_per_mille: 50,
        fsync_delay_per_mille: 80,
        fsync_delay_micros: 300,
        conn_reset_per_mille: 40,
        panic_per_mille: 35,
        panic_prefix: Some(SOAK_PREFIX),
        clock_skew_ms: 0,
    });

    // Drive the soak. An Ok push is *acknowledged*; an Err push (the
    // connection died after the frame went out) is *unknown-outcome* —
    // with this fault plan the reset strikes before dispatch, so those
    // batches were not applied, and the accounting below can be exact.
    let mut acked_samples: u64 = 0;
    let mut unknown_batches: u64 = 0;
    let mut last_t = vec![0u64; streams.len()];
    for b in 0..BATCHES {
        let s = b % streams.len();
        let data: Vec<f64> = (0..BATCH * DIM).map(|i| sample(s, b, i)).collect();
        match rc.push_many(&streams[s], BATCH, &data) {
            Ok((accepted, dropped)) => {
                assert_eq!(accepted as usize, BATCH, "block policy accepts whole batches");
                assert_eq!(dropped, 0);
                acked_samples += accepted;
            }
            Err(ClientError::Io(_)) => unknown_batches += 1,
            Err(e) => panic!("batch {b}: unexpected fatal error: {e}"),
        }
        // Anytime availability: estimates stay queryable mid-chaos and
        // per-stream applied counts never move backwards.
        if b % 40 == 20 {
            let snap = rc.snapshot(&streams[s]).expect("snapshot under chaos");
            assert!(snap.t >= last_t[s], "applied count went backwards");
            last_t[s] = snap.t;
        }
    }
    chaos::disarm();
    let torn = chaos::injected(chaos::Site::TornWrite);
    let panics = chaos::injected(chaos::Site::WorkerPanic);
    let resets = chaos::injected(chaos::Site::ConnReset);
    let fsync_errs = chaos::injected(chaos::Site::FsyncError);
    // The fixed seed pins the whole schedule; at these rates the first
    // firing of every site lands well inside a ~240-decision soak.
    assert!(torn > 0, "no torn writes injected");
    assert!(panics > 0, "no worker panics injected");
    assert!(resets > 0, "no connection resets injected");
    assert!(fsync_errs > 0, "no fsync errors injected");
    assert!(unknown_batches > 0, "resets should have killed some pushes");
    assert!(rc.reconnects() > 1, "resets should have forced reconnects");

    // Settle and take the live truth directly from the coordinator.
    rc.sync().expect("post-chaos sync");
    drop(rc);
    let mut live_t: u64 = 0;
    let mut live_dropped: u64 = 0;
    for name in &streams {
        let snap = coordinator.snapshot(name).expect("live snapshot");
        live_t += snap.t;
        live_dropped += snap.dropped;
    }
    // Invariant 1 — nothing vanishes: every acknowledged sample is in
    // live state or in the drop counters, and nothing else is.
    assert_eq!(
        live_t + live_dropped,
        acked_samples,
        "acked samples must equal applied + dropped"
    );
    // Invariant 2 — drops are exactly the quarantined panic batches.
    assert_eq!(live_dropped, panics * BATCH as u64);
    let metrics = coordinator.metrics().export();
    assert_eq!(counter(&metrics, "shard_restarts"), panics);
    assert_eq!(counter(&metrics, "quarantined_batches"), panics);
    assert_eq!(counter(&metrics, "poisoned_streams"), 0);

    // Tear the stack down cleanly and recover from disk.
    drop(server);
    drop(coordinator);
    let (recovered, report) = Coordinator::recover(&cfg).expect("recover");
    let mut recovered_t: u64 = 0;
    let mut first: Vec<(u64, Option<Vec<u64>>)> = Vec::new();
    for name in &streams {
        let snap = recovered.snapshot(name).expect("recovered snapshot");
        recovered_t += snap.t;
        first.push((snap.t, snap.value.as_deref().map(bits)));
    }
    // Invariant 3 — recovery loses exactly the torn-away WAL records:
    // each torn append was one whole batch, applied live but healed
    // (rotated) out of the log.
    assert_eq!(
        recovered_t,
        live_t - torn * BATCH as u64,
        "recovery must lose exactly the torn appends \
         (report: {report:?})"
    );
    // Torn tails are either skipped mid-log or end the final segment;
    // zero-byte tears leave the log clean. All are legal — just
    // bounded.
    assert!(report.wal_skipped_tails <= torn);
    drop(recovered);

    // Invariant 4 — recovery is deterministic: a second recovery (now
    // reading the first one's checkpoint) reproduces every estimate
    // bit for bit.
    let (again, _) = Coordinator::recover(&cfg).expect("second recover");
    for (name, (t, value)) in streams.iter().zip(&first) {
        let snap = again.snapshot(name).expect("re-recovered snapshot");
        assert_eq!(snap.t, *t, "{name}: applied count changed across recoveries");
        assert_eq!(
            snap.value.as_deref().map(bits).as_ref(),
            value.as_ref(),
            "{name}: estimate changed across recoveries"
        );
    }
}

/// A disk that stalls 15 ms per fsync turns a 2-deep Reject queue into
/// a deterministic overload: plain clients on both protocol
/// generations must see the structured `Overloaded` rejection (not a
/// generic error), the server must count it, and a retrying client
/// must ride it out with backoff instead of failing.
#[test]
fn slow_disk_overload_sheds_load_and_retrying_client_rides_it_out() {
    let _guard = chaos::test_mutex().lock().unwrap_or_else(|e| e.into_inner());
    chaos::disarm();
    let dir = temp_dir("chaos-overload");
    let cfg = soak_cfg(&dir, 1, 2, BackpressurePolicy::Reject);
    let coordinator = Arc::new(Coordinator::from_config(&cfg).expect("durable coordinator"));
    let server = Server::start_with_options(
        "127.0.0.1:0",
        Arc::clone(&coordinator),
        4,
        ServerOptions::default(),
    )
    .expect("server");
    let addr = server.addr().to_string();

    let mut v2 = Client::connect_with(&addr, ProtocolChoice::V2).expect("v2 client");
    v2.register("ov", 1, "gea(c=0.5)").expect("register");
    v2.sync().expect("sync");

    // Every WAL append now stalls 15 ms, so the single shard worker
    // drains at most ~66 batches/s while clients push thousands — the
    // queue overflows on schedule, no timing luck involved.
    chaos::arm(chaos::ChaosPlan {
        seed: 0x510_D15C,
        fsync_delay_per_mille: 1000,
        fsync_delay_micros: 15_000,
        ..Default::default()
    });

    let mut acked: u64 = 0;
    let mut shed_v2: u64 = 0;
    for b in 0..60 {
        match v2.push_many("ov", 2, &[b as f64, b as f64 + 0.5]) {
            Ok((accepted, dropped)) => {
                assert_eq!((accepted, dropped), (2, 0));
                acked += accepted;
            }
            Err(ClientError::Overloaded(_)) => shed_v2 += 1,
            Err(e) => panic!("v2 push {b}: expected Overloaded, got: {e}"),
        }
    }
    assert!(shed_v2 > 0, "a 2-deep queue behind a 15ms disk must shed load");

    // The v1 JSON protocol surfaces the same structured rejection.
    let mut v1 = Client::connect_with(&addr, ProtocolChoice::V1).expect("v1 client");
    let mut shed_v1: u64 = 0;
    for b in 0..60 {
        match v1.push_many("ov", 2, &[b as f64, b as f64 + 0.25]) {
            Ok((accepted, _)) => acked += accepted,
            Err(ClientError::Overloaded(_)) => shed_v1 += 1,
            Err(e) => panic!("v1 push {b}: expected Overloaded, got: {e}"),
        }
    }
    assert!(shed_v1 > 0, "v1 must see structured overload too");

    // A retrying client pushes through the same storm: every batch
    // lands eventually, with backoff sleeps recorded along the way.
    let mut rc = RetryingClient::with_policy(
        &addr,
        ProtocolChoice::Auto,
        RetryPolicy {
            max_attempts: 200,
            base_backoff_ms: 2,
            max_backoff_ms: 40,
            seed: 0xBACC_0FF,
        },
    );
    for b in 0..8 {
        let (accepted, dropped) = rc
            .push_many("ov", 2, &[b as f64 * 1.5, b as f64 * 1.5 + 1.0])
            .expect("retrying client must outlast the overload");
        assert_eq!((accepted, dropped), (2, 0));
        acked += accepted;
    }
    assert!(
        rc.overload_backoffs() > 0,
        "the storm should have forced at least one overload backoff"
    );

    chaos::disarm();
    v2.sync().expect("drain");
    // Reject never half-applies: applied == acked exactly, and the
    // server counted every structured rejection it sent.
    let snap = v2.snapshot("ov").expect("snapshot");
    assert_eq!(snap.t, acked, "Reject must be all-or-nothing per batch");
    let doc = v2.metrics().expect("metrics");
    let shed_seen = doc
        .get("metrics")
        .map(|m| counter(m, "wire_overloaded_responses"))
        .unwrap_or(0);
    assert!(
        shed_seen >= shed_v2 + shed_v1,
        "server must count shed responses ({shed_seen} < {})",
        shed_v2 + shed_v1
    );
    drop(server);
}

/// Forensics: when an injected worker panic quarantines a batch, the
/// flight-recorder ring the panic handler dumps must still hold that
/// batch's trace_id — the whole point of the recorder is that the
/// operator can join the panic report back to the request that died.
/// End-to-end: the trace is minted by the client, echoed in the ack,
/// and must reappear on the `quarantine` event in the introspect
/// snapshot of the same ring.
#[test]
fn quarantining_panic_leaves_its_trace_in_the_flight_ring() {
    let _guard = chaos::test_mutex().lock().unwrap_or_else(|e| e.into_inner());
    chaos::disarm();
    let c = Arc::new(Coordinator::new(1, 64, BackpressurePolicy::Block));
    let server =
        Server::start_with_options("127.0.0.1:0", Arc::clone(&c), 2, ServerOptions::default())
            .expect("server");
    let addr = server.addr().to_string();
    let mut cl = Client::connect(&addr).expect("client");
    let stream = format!("{SOAK_PREFIX}trace");
    cl.register(&stream, 2, "gea(c=0.5)").expect("register");
    cl.sync().expect("pre-chaos sync");

    // Every prefixed batch panics its worker mid-apply — one push, one
    // deterministic quarantine.
    chaos::arm(chaos::ChaosPlan {
        seed: 0x7AC3_D00D,
        panic_per_mille: 1000,
        panic_prefix: Some(SOAK_PREFIX),
        ..Default::default()
    });
    cl.push_many(&stream, 2, &[1.0, 2.0, 3.0, 4.0])
        .expect("block policy acks at enqueue, before the panic");
    let trace = cl.last_trace_id();
    assert_ne!(trace, 0, "the ack must echo the minted trace");
    cl.sync().expect("post-panic sync");
    chaos::disarm();
    assert!(
        chaos::injected(chaos::Site::WorkerPanic) > 0,
        "the prefixed batch must have panicked its worker"
    );

    // The ring the panic handler dumped is the same one introspect
    // snapshots: the quarantine event carries the request's trace.
    let report = cl.introspect().expect("introspect");
    assert!(
        report
            .events
            .iter()
            .any(|e| e.kind == EventKind::Quarantine && e.trace_id == trace),
        "quarantine event with trace_id={trace} missing from ring: {:?}",
        report.events
    );
    // And the batch's samples are surfaced as drops, not vanished.
    let snap = cl.snapshot(&stream).expect("snapshot");
    assert_eq!((snap.t, snap.dropped), (0, 2));
    drop(server);
}
