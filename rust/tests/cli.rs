//! Integration tests of the `ata` launcher binary itself.

use std::process::Command;

fn ata() -> Command {
    Command::new(env!("CARGO_BIN_EXE_ata"))
}

fn run(args: &[&str]) -> (bool, String, String) {
    let out = ata().args(args).output().expect("spawn ata");
    (
        out.status.success(),
        String::from_utf8_lossy(&out.stdout).to_string(),
        String::from_utf8_lossy(&out.stderr).to_string(),
    )
}

#[test]
fn help_lists_commands() {
    let (ok, stdout, _) = run(&["--help"]);
    assert!(ok);
    for cmd in [
        "experiment",
        "serve",
        "client",
        "query",
        "checkpoint",
        "restore",
        "artifacts",
        "weights",
    ] {
        assert!(stdout.contains(cmd), "help missing '{cmd}':\n{stdout}");
    }
}

#[test]
fn query_command_reports_stats_and_bands() {
    use ata::config::BackpressurePolicy;
    use ata::coordinator::{Coordinator, Server};
    use std::sync::Arc;
    let c = Arc::new(Coordinator::new(2, 64, BackpressurePolicy::Block));
    for (name, level) in [("q/a", 1.0), ("q/b", -1.0)] {
        c.register(name, 1, ata::averagers::AveragerSpec::Gea { c: 0.5 })
            .unwrap();
        for i in 0..30 {
            c.push(name, vec![level + (i as f64 * 0.3).sin() * 0.2]).unwrap();
        }
    }
    c.sync().unwrap();
    let server = Server::start("127.0.0.1:0", Arc::clone(&c), 2).expect("server");
    let addr = server.addr().to_string();
    // Prefix query with aggregate.
    let (ok, stdout, stderr) = run(&[
        "query", "--addr", &addr, "--prefix", "q/", "--aggregate",
    ]);
    assert!(ok, "stderr: {stderr}");
    assert!(stdout.contains("q/a") && stdout.contains("q/b"), "{stdout}");
    assert!(stdout.contains("±"), "bands printed: {stdout}");
    assert!(stdout.contains("<aggregate>"), "{stdout}");
    // Explicit list → multi_snapshot; unknown entries error per row.
    let (ok, stdout, _) = run(&[
        "query", "--addr", &addr, "--streams", "q/a,ghost",
    ]);
    assert!(ok, "{stdout}");
    assert!(stdout.contains("q/a") && stdout.contains("ghost"), "{stdout}");
    assert!(stdout.contains("error"), "{stdout}");
}

#[test]
fn unknown_command_fails_cleanly() {
    let (ok, _, stderr) = run(&["frobnicate"]);
    assert!(!ok);
    assert!(stderr.contains("unknown command"), "{stderr}");
}

#[test]
fn experiment_smoke_run_with_csv_export() {
    let csv = std::env::temp_dir().join("ata-cli-test.csv");
    let _ = std::fs::remove_file(&csv);
    let (ok, stdout, stderr) = run(&[
        "experiment",
        "--figure",
        "fig3",
        "--c",
        "0.5",
        "--runs",
        "2",
        "--steps",
        "120",
        "--eval-points",
        "12",
        "--csv",
        csv.to_str().unwrap(),
    ]);
    assert!(ok, "stderr: {stderr}");
    assert!(stdout.contains("final excess error"), "{stdout}");
    assert!(stdout.contains("gea(c=0.5)"), "{stdout}");
    let contents = std::fs::read_to_string(&csv).expect("csv written");
    assert!(contents.starts_with("step,"), "{contents}");
    assert!(contents.lines().count() > 5);
}

#[test]
fn experiment_rejects_bad_figure() {
    let (ok, _, stderr) = run(&["experiment", "--figure", "fig9", "--runs", "1"]);
    assert!(!ok);
    assert!(stderr.contains("unknown figure"), "{stderr}");
}

#[test]
fn weights_analysis_reports_invariants() {
    let (ok, stdout, stderr) = run(&["weights", "--spec", "awa3(c=0.5)", "--t", "60"]);
    assert!(ok, "stderr: {stderr}");
    assert!(stdout.contains("weight sum"), "{stdout}");
    assert!(stdout.contains("effective samples"), "{stdout}");
    // Σα = 1 printed with 9 decimals
    assert!(stdout.contains("1.000000000"), "{stdout}");
}

#[test]
fn weights_rejects_bad_spec() {
    let (ok, _, stderr) = run(&["weights", "--spec", "bogus(c=0.5)"]);
    assert!(!ok);
    assert!(stderr.contains("bogus"), "{stderr}");
}

#[test]
fn artifacts_validation_when_present() {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("SKIP: artifacts not built");
        return;
    }
    let (ok, stdout, stderr) = run(&["artifacts"]);
    assert!(ok, "stderr: {stderr}");
    assert!(stdout.contains("all artifacts load and execute"), "{stdout}");
}

#[test]
fn experiment_config_file_via_cli() {
    let path = std::env::temp_dir().join("ata-cli-exp.toml");
    std::fs::write(
        &path,
        "steps = 60\nruns = 2\naveragers = [\"gea(c=0.5)\", \"true(c=0.5)\"]\n\n[schedule]\nkind = \"stride\"\nstride = 20\n",
    )
    .unwrap();
    let (ok, stdout, stderr) = run(&["experiment", "--config", path.to_str().unwrap()]);
    assert!(ok, "stderr: {stderr}");
    assert!(stdout.contains("gea(c=0.5)"), "{stdout}");
}
