//! Cluster federation end-to-end: scatter-gather routing equivalence,
//! WAL-shipping replication with bitwise-exact failover, a 3-node
//! kill-and-failover soak under chaos, and deterministic live stream
//! migration with pushes injected at the worst possible moments.

use ata::averagers::AveragerSpec;
use ata::cluster::{migrate_stream_observed, HashRing, MigratePhase, Router, Shipper, Standby};
use ata::config::{BackpressurePolicy, PersistConfig, ServiceConfig};
use ata::coordinator::{
    Coordinator, MultiOutcome, ProtocolChoice, RetryPolicy, RetryingClient, Server,
};
use ata::metrics::names;
use ata::testkit::{chaos, temp_dir};
use std::path::Path;
use std::sync::Arc;

/// Every estimator family in its wire spec-string form (mirrors
/// `all_specs()` in persist_recovery.rs — both window kinds where
/// applicable, banked and slotted).
fn all_spec_strings() -> Vec<&'static str> {
    vec![
        "exp(g=0.9)",
        "expk(k=10)",
        "gea(c=0.5)",
        "awa2(k=7)",
        "awa3(c=0.4)",
        "true(k=9)",
        "true(c=0.5)",
        "raw(c=0.5,T=200)",
        "restart(k=6)",
        "eh(k=50,eps=0.1)",
        "twotail(r=0.5)",
    ]
}

/// Deterministic sample value for stream `s`, step `t`, dimension `i`.
fn sample(s: usize, t: u64, i: usize) -> f64 {
    (((t as f64) * 0.37 + (s as f64) * 1.7 + (i as f64) * 0.41).sin()) * 3.0
}

fn flat_batch(s: usize, start_t: u64, count: usize, d: usize) -> Vec<f64> {
    let mut out = Vec::with_capacity(count * d);
    for k in 0..count {
        for i in 0..d {
            out.push(sample(s, start_t + k as u64, i));
        }
    }
    out
}

fn close(a: &[f64], b: &[f64], ctx: &str) {
    assert_eq!(a.len(), b.len(), "{ctx}: length");
    for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
        assert!(
            (x - y).abs() <= 1e-12 * y.abs().max(1.0),
            "{ctx}[{i}]: {x} vs {y}"
        );
    }
}

/// Tight backoff so retry storms in tests resolve in milliseconds.
fn fast_policy(seed: u64) -> RetryPolicy {
    RetryPolicy {
        max_attempts: 10,
        base_backoff_ms: 1,
        max_backoff_ms: 20,
        seed,
    }
}

fn persist_cfg(dir: &Path, shards: usize) -> ServiceConfig {
    ServiceConfig {
        shards,
        queue_capacity: 256,
        persist: Some(PersistConfig {
            dir: dir.display().to_string(),
            segment_bytes: 16 << 10,
            fsync: false,
            checkpoint_interval_ms: 0,
            group_commit_micros: 0,
        }),
        ..Default::default()
    }
}

fn in_memory() -> Arc<Coordinator> {
    Arc::new(Coordinator::new(2, 256, BackpressurePolicy::Block))
}

fn serve(c: &Arc<Coordinator>) -> Server {
    Server::start_with("127.0.0.1:0", Arc::clone(c), 2, ProtocolChoice::Auto).expect("server")
}

fn client(addr: &str, seed: u64) -> RetryingClient {
    RetryingClient::with_policy(addr, ProtocolChoice::Auto, fast_policy(seed))
}

fn value_bits(snap: &ata::coordinator::Snapshot) -> Vec<u64> {
    snap.value
        .as_ref()
        .expect("snapshot has a value")
        .iter()
        .map(|v| v.to_bits())
        .collect()
}

// ---------------------------------------------------------------------------
// 1. Federated scatter-gather == single node holding the union of streams
// ---------------------------------------------------------------------------

#[test]
fn federated_scatter_gather_matches_single_node() {
    let nodes: Vec<Arc<Coordinator>> = (0..3).map(|_| in_memory()).collect();
    let servers: Vec<Server> = nodes.iter().map(serve).collect();
    let reference = in_memory();
    let ref_server = serve(&reference);

    let mut ring = HashRing::new(64);
    for (i, s) in servers.iter().enumerate() {
        ring.add_node(&format!("n{i}"), &s.addr().to_string())
            .expect("add node");
    }
    let mut router = Router::with_ring(ring, fast_policy(0xFED1));
    let mut ref_cl = client(&ref_server.addr().to_string(), 0xFED2);

    let specs = all_spec_strings();
    let d = 3;
    let names: Vec<String> = (0..specs.len()).map(|i| format!("fed/s{i:02}")).collect();
    for (name, spec) in names.iter().zip(&specs) {
        router.register(name, d, spec).expect("routed register");
        ref_cl.register(name, d, spec).expect("reference register");
    }
    // The hash placement must actually federate: the streams may not
    // all land on one node or the test would prove nothing.
    let placed: std::collections::BTreeSet<String> = names
        .iter()
        .map(|n| router.route(n).expect("route"))
        .collect();
    assert!(
        placed.len() >= 2,
        "{} streams should spread over >1 of 3 nodes, got {placed:?}",
        names.len()
    );

    let mut t0 = 0u64;
    for round in 0..3usize {
        let count = 5 + round;
        let data: Vec<Vec<f64>> = (0..names.len())
            .map(|s| flat_batch(s, t0, count, d))
            .collect();
        let batches: Vec<(&str, usize, &[f64])> = names
            .iter()
            .zip(&data)
            .map(|(n, b)| (n.as_str(), count, b.as_slice()))
            .collect();
        for o in router.multi_push(&batches).expect("federated multi_push") {
            assert_eq!(o, MultiOutcome::Accepted, "federated push outcome");
        }
        for o in ref_cl.multi_push(&batches).expect("reference multi_push") {
            assert_eq!(o, MultiOutcome::Accepted, "reference push outcome");
        }
        t0 += count as u64;
    }
    router.sync().expect("federated sync");
    ref_cl.sync().expect("reference sync");

    // Per-stream reads: fan-in multi_snapshot must equal the reference,
    // entry for entry, to 1e-12 on every statistical field.
    let name_refs: Vec<&str> = names.iter().map(String::as_str).collect();
    let fed = router.multi_snapshot(&name_refs).expect("federated snaps");
    let single = ref_cl.multi_snapshot(&name_refs).expect("reference snaps");
    for ((name, f), s) in names.iter().zip(&fed).zip(&single) {
        let f = f.as_ref().expect("federated entry ok");
        let s = s.as_ref().expect("reference entry ok");
        assert_eq!(f.stream, *name);
        assert_eq!(f.t, s.t, "{name}: sample count");
        close(&[f.ess], &[s.ess], &format!("{name}: ess"));
        close(
            &[f.effective_window],
            &[s.effective_window],
            &format!("{name}: window"),
        );
        close(&f.mean, &s.mean, &format!("{name}: mean"));
        close(&f.variance, &s.variance, &format!("{name}: variance"));
        close(&f.band, &s.band, &format!("{name}: band"));
    }

    // Federated analytics query: same streams, same ESS-weighted pool.
    let fq = router.query("fed/", 2.0, 0, true).expect("federated query");
    let (rstats, ragg) = ref_cl.query("fed/", 2.0, 0, true).expect("reference query");
    assert_eq!(fq.stats.len(), rstats.len(), "query row count");
    assert_eq!(fq.aggregated, rstats.len(), "pool absorbed every stream");
    for (f, r) in fq.stats.iter().zip(&rstats) {
        assert_eq!(f.stream, r.stream, "query row order");
        close(&f.mean, &r.mean, &format!("query {}: mean", f.stream));
    }
    let fagg = fq.aggregate.expect("federated aggregate");
    let ragg = ragg.expect("reference aggregate");
    close(&fagg.mean, &ragg.mean, "aggregate mean");
    close(&fagg.variance, &ragg.variance, "aggregate variance");
    close(&[fagg.ess], &[ragg.ess], "aggregate ess");

    // Top-K deviation ranking must agree on the ordering too.
    let ftop = router.query("fed/", 2.0, 3, false).expect("federated top-k");
    let (rtop, _) = ref_cl.query("fed/", 2.0, 3, false).expect("reference top-k");
    let fnames: Vec<&str> = ftop.stats.iter().map(|e| e.stream.as_str()).collect();
    let rnames: Vec<&str> = rtop.stats.iter().map(|e| e.stream.as_str()).collect();
    assert_eq!(fnames, rnames, "top-k order");
}

// ---------------------------------------------------------------------------
// 2. WAL shipping → promote: bitwise-identical stats at the shipped
//    boundary, acked-but-unshipped loss exactly accounted
// ---------------------------------------------------------------------------

#[test]
fn ship_and_promote_restores_shipped_boundary_bitwise() {
    let dir_p = temp_dir("fed-ship-primary");
    let dir_s = temp_dir("fed-ship-standby");
    let cfg = persist_cfg(&dir_p, 2);
    let primary = Arc::new(Coordinator::from_config(&cfg).expect("primary"));

    let d = 2;
    let names: Vec<String> = (0..all_spec_strings().len())
        .map(|i| format!("rep/s{i:02}"))
        .collect();
    for (s, (name, spec)) in names.iter().zip(all_spec_strings()).enumerate() {
        let spec = AveragerSpec::parse(spec).expect("spec");
        primary.register(name, d, spec).expect("register");
        primary
            .push_many(name, 30, &flat_batch(s, 0, 30, d))
            .expect("phase-1 push");
    }
    primary.sync().expect("sync phase 1");

    let standby = Standby::start("127.0.0.1:0", &dir_s).expect("standby");
    let mut shipper = Shipper::new(
        Arc::clone(&primary),
        client(&standby.addr().to_string(), 0x51319),
    )
    .expect("shipper");
    // Tiny chunks: every segment crosses many wal_ship frames, so the
    // conditional-append resync path is actually exercised.
    shipper.set_chunk_bytes(64);
    let report = shipper.ship_once().expect("ship pass");
    assert!(report.bytes > 0, "phase 1 must ship bytes");
    assert!(report.chunks > 1, "64-byte chunks must take several frames");
    assert_eq!(report.lag_bytes, 0, "shipped to the committed horizon");
    assert_eq!(
        standby.received_bytes(),
        report.bytes,
        "standby accounting matches the shipper's"
    );

    // A second pass with nothing new is a no-op (cursors, not re-ships).
    let idle = shipper.ship_once().expect("idle pass");
    assert_eq!((idle.chunks, idle.bytes, idle.lag_bytes), (0, 0, 0));

    // The standby is not a coordinator: data-plane ops are refused.
    let mut probe = client(&standby.addr().to_string(), 0x51320);
    probe.ping().expect("standby answers ping");
    let err = probe.list_streams().expect_err("standby refuses data ops");
    assert!(
        err.to_string().contains("unsupported op"),
        "refusal names the op: {err}"
    );

    // Ground truth at the shipped boundary.
    let shipped: Vec<(u64, Vec<u64>)> = names
        .iter()
        .map(|n| {
            let s = primary.snapshot(n).expect("snapshot");
            (s.t, value_bits(&s))
        })
        .collect();

    // Phase 2: acked on the primary but never shipped.
    for (s, name) in names.iter().enumerate() {
        primary
            .push_many(name, 7, &flat_batch(s, 30, 7, d))
            .expect("phase-2 push");
    }
    primary.sync().expect("sync phase 2");
    let t_lost = 7u64;

    // Kill the primary without another ship pass, then promote.
    drop(shipper);
    drop(primary);
    let (promoted, recovery) = standby.promote(persist_cfg(&dir_p, 2)).expect("promote");
    assert!(recovery.wal_clean, "shipped WAL replays clean");
    assert!(recovery.replayed_samples > 0, "replay did the rebuild");

    for (name, (t1, bits)) in names.iter().zip(&shipped) {
        let snap = promoted.snapshot(name).expect("promoted snapshot");
        assert_eq!(*t1, 30, "{name}: shipped boundary is end of phase 1");
        assert_eq!(
            snap.t,
            37 - t_lost,
            "{name}: loss is exactly the acked-but-unshipped phase 2"
        );
        assert_eq!(
            value_bits(&snap),
            *bits,
            "{name}: promoted stats are bitwise-identical at the shipped boundary"
        );
    }

    // The promoted node exposes where replay started (standby lag
    // observability) and counts the failover.
    let intro = promoted.introspect();
    assert_eq!(intro.wal_skipped_tails, 0, "no mid-WAL corruption");
    assert!(
        intro
            .shards
            .iter()
            .any(|s| s.wal_replay_segment > 0 || s.wal_replay_offset > 0),
        "replay position surfaced in introspect"
    );
    assert_eq!(
        promoted
            .metrics()
            .counter(names::CLUSTER_FAILOVERS)
            .get(),
        1
    );
}

// ---------------------------------------------------------------------------
// 3. Three nodes, chaos, kill n0, promote its standby, repoint the ring
// ---------------------------------------------------------------------------

#[test]
fn kill_and_failover_under_chaos_keeps_ring_and_stats() {
    let _guard = chaos::test_mutex().lock().unwrap_or_else(|e| e.into_inner());
    chaos::disarm();

    let dir0 = temp_dir("fed-chaos-primary");
    let dir_s = temp_dir("fed-chaos-standby");
    let c0 = Arc::new(Coordinator::from_config(&persist_cfg(&dir0, 2)).expect("n0"));
    let c1 = in_memory();
    let c2 = in_memory();
    let s0 = serve(&c0);
    let s1 = serve(&c1);
    let s2 = serve(&c2);

    let mut ring = HashRing::new(64);
    ring.add_node("n0", &s0.addr().to_string()).expect("n0");
    ring.add_node("n1", &s1.addr().to_string()).expect("n1");
    ring.add_node("n2", &s2.addr().to_string()).expect("n2");
    let mut router = Router::with_ring(ring, fast_policy(0xC0A5));
    let v0 = router.ring().version();

    let d = 2;
    let names: Vec<String> = (0..24).map(|i| format!("ko/s{i:02}")).collect();
    for name in &names {
        router.register(name, d, "gea(c=0.5)").expect("register");
    }
    let on_n0: Vec<String> = names
        .iter()
        .filter(|n| router.route(n).expect("route") == "n0")
        .cloned()
        .collect();
    assert!(
        !on_n0.is_empty(),
        "24 streams over 3 nodes must place some on n0"
    );

    // Connection resets only: the retrying client rides them out, and
    // exactness is judged against what actually landed on n0 (captured
    // after disarm), so duplicated retries cannot fail the test.
    chaos::arm(chaos::ChaosPlan {
        seed: 0xFA110FF,
        conn_reset_per_mille: 80,
        ..Default::default()
    });
    let mut t0 = 0u64;
    for round in 0..40usize {
        let count = 1 + round % 3;
        let data: Vec<Vec<f64>> = (0..names.len())
            .map(|s| flat_batch(s, t0, count, d))
            .collect();
        let batches: Vec<(&str, usize, &[f64])> = names
            .iter()
            .zip(&data)
            .map(|(n, b)| (n.as_str(), count, b.as_slice()))
            .collect();
        router.multi_push(&batches).expect("push under chaos");
        t0 += count as u64;
    }
    chaos::disarm();
    router.sync().expect("settle after chaos");

    // Ground truth from n0 itself, then replicate and kill it.
    let truth: Vec<(String, u64, Vec<u64>)> = on_n0
        .iter()
        .map(|n| {
            let s = c0.snapshot(n).expect("n0 snapshot");
            (n.clone(), s.t, value_bits(&s))
        })
        .collect();
    let standby = Standby::start("127.0.0.1:0", &dir_s).expect("standby");
    let mut shipper =
        Shipper::new(Arc::clone(&c0), client(&standby.addr().to_string(), 0x5311)).expect("shipper");
    let report = shipper.ship_once().expect("ship");
    assert_eq!(report.lag_bytes, 0, "fully caught up before the kill");
    drop(shipper);
    drop(s0);
    drop(c0);

    let (promoted, _) = standby.promote(persist_cfg(&dir0, 2)).expect("promote");
    let promoted = Arc::new(promoted);
    let new_s0 = serve(&promoted);

    // Repoint the ring: same node id, new address, bumped version,
    // gossiped to the survivors in the same call.
    let v1 = router
        .failover("n0", &new_s0.addr().to_string())
        .expect("failover");
    assert!(v1 > v0, "failover re-versions the ring ({v0} -> {v1})");

    // The routed reads now come off the promoted node, bit-for-bit.
    for (name, t, bits) in &truth {
        assert_eq!(router.route(name).expect("route"), "n0", "{name}: placement unchanged");
        let snap = client(&new_s0.addr().to_string(), 0x5312)
            .snapshot(name)
            .expect("promoted snapshot");
        assert_eq!(snap.t, *t, "{name}: t survives failover");
        assert_eq!(value_bits(&snap), *bits, "{name}: bitwise across failover");
    }
    // Fan-in still covers every stream, including the failed-over ones.
    let name_refs: Vec<&str> = names.iter().map(String::as_str).collect();
    for (name, entry) in names.iter().zip(router.multi_snapshot(&name_refs).expect("snaps")) {
        entry.unwrap_or_else(|e| panic!("{name}: post-failover snapshot: {e}"));
    }
    // Survivors learned the new ring version via the gossip round.
    let prom_text = client(&s1.addr().to_string(), 0x5313)
        .metrics_prometheus()
        .expect("n1 prometheus");
    assert!(
        prom_text.contains(names::CLUSTER_RING_VERSION),
        "ring version gauge exported on survivors"
    );
    assert_eq!(
        promoted.metrics().counter(names::CLUSTER_FAILOVERS).get(),
        1
    );
}

// ---------------------------------------------------------------------------
// 4. Live migration with pushes landing at both race points
// ---------------------------------------------------------------------------

#[test]
fn live_migration_dedups_delta_exactly_under_concurrent_pushes() {
    let dir0 = temp_dir("fed-mig-src");
    let src_shards = 2usize;
    let c0 = Arc::new(Coordinator::from_config(&persist_cfg(&dir0, src_shards)).expect("n0"));
    let c1 = in_memory();
    let s0 = serve(&c0);
    let s1 = serve(&c1);

    let mut ring = HashRing::new(64);
    ring.add_node("n0", &s0.addr().to_string()).expect("n0");
    ring.add_node("n1", &s1.addr().to_string()).expect("n1");
    let mut router = Router::with_ring(ring, fast_policy(0x316));

    // A banked estimator, and a name the ring places on the source.
    let d = 2;
    let spec = "awa3(k=16)";
    let name = (0..64)
        .map(|i| format!("mig/s{i:02}"))
        .find(|n| router.route(n).expect("route") == "n0")
        .expect("some name routes to n0");
    router.register(&name, d, spec).expect("register");

    let base = 20usize;
    let batch0 = flat_batch(0, 0, base, d);
    let batches: Vec<(&str, usize, &[f64])> = vec![(name.as_str(), base, batch0.as_slice())];
    router.multi_push(&batches).expect("base push");
    router.sync().expect("base sync");

    // A writer that keeps pushing straight at the source mid-migration:
    // 7 samples land before the export (double-covered: they are in the
    // WAL delta range AND in the exported state) and 5 after the
    // restore (pure delta). The tail-take must dedup to exactly 5.
    let mut writer = client(&s0.addr().to_string(), 0xA11CE);
    let wal_root = dir0.join("wal");
    let report = migrate_stream_observed(
        &mut router,
        &name,
        "n1",
        d,
        spec,
        Some((wal_root.as_path(), src_shards)),
        |phase| {
            let (start, count) = match phase {
                MigratePhase::BeforeExport => (base as u64, 7usize),
                MigratePhase::BeforeSwitch => (base as u64 + 7, 5usize),
            };
            let data = flat_batch(0, start, count, d);
            let (accepted, dropped) = writer
                .push_many(&name, count, &data)
                .map_err(|e| format!("racing push: {e}"))?;
            if accepted != count as u64 || dropped > 0 {
                return Err(format!("racing push shed: {accepted}/{count}"));
            }
            writer.sync().map_err(|e| format!("racing sync: {e}"))
        },
    )
    .expect("migration");

    assert_eq!(report.from, "n0");
    assert_eq!(report.to, "n1");
    assert_eq!(
        report.delta_samples, 5,
        "exactly the post-restore pushes replay; the pre-export ones dedup"
    );
    assert_eq!(router.route(&name).expect("route"), "n1", "pin switched placement");
    assert_eq!(router.ring().version(), report.ring_version);

    // Target carries the full history; source froze at the same point.
    let total = base as u64 + 12;
    let src_snap = c0.snapshot(&name).expect("source snapshot");
    let dst_snap = c1.snapshot(&name).expect("target snapshot");
    assert_eq!(src_snap.t, total, "source saw every racing push");
    assert_eq!(dst_snap.t, total, "target caught up to the source exactly");
    let src_val: Vec<f64> = src_snap.value.as_ref().expect("src value").to_vec();
    let dst_val: Vec<f64> = dst_snap.value.as_ref().expect("dst value").to_vec();
    close(&dst_val, &src_val, "migrated estimate");

    // New pushes land on the target only.
    let after = flat_batch(0, total, 1, d);
    let post: Vec<(&str, usize, &[f64])> = vec![(name.as_str(), 1, after.as_slice())];
    router.multi_push(&post).expect("post-migration push");
    router.sync().expect("post-migration sync");
    assert_eq!(c1.snapshot(&name).expect("target").t, total + 1);
    assert_eq!(c0.snapshot(&name).expect("source").t, total, "source is frozen");

    // The federated view counts the stream once, from its new home
    // (the frozen source copy is placement-filtered out).
    let fq = router.query("mig/", 2.0, 0, true).expect("federated query");
    assert_eq!(fq.stats.len(), 1, "one row for the migrated stream");
    assert_eq!(fq.stats[0].t, total + 1, "the row is the target's copy");
    assert_eq!(fq.aggregated, 1);
}

// ---------------------------------------------------------------------------
// 5. Standby promote mints a new handle era; one stale rejection must
//    heal EVERY cached handle, not just the rejected stream's
// ---------------------------------------------------------------------------

#[test]
fn promote_invalidates_all_cached_handles_in_one_purge() {
    let dir_p = temp_dir("fed-era-primary");
    let dir_s = temp_dir("fed-era-standby");
    let primary = Arc::new(Coordinator::from_config(&persist_cfg(&dir_p, 2)).expect("primary"));
    let server_p = serve(&primary);

    let d = 2;
    let names = ["era/true", "era/twotail", "era/gea"];
    let specs = ["true(k=9)", "twotail(r=0.5)", "gea(c=0.5)"];
    let mut cl = client(&server_p.addr().to_string(), 0xE7A1);
    let era1: Vec<u64> = names
        .iter()
        .zip(specs)
        .map(|(n, s)| cl.register(n, d, s).expect("era-1 register"))
        .collect();
    for (s, name) in names.iter().enumerate() {
        let got = cl.push_many(name, 20, &flat_batch(s, 0, 20, d)).expect("era-1 push");
        assert_eq!(got, (20, 0), "{name}");
    }
    cl.sync().expect("era-1 sync");

    // Replicate, fence, promote: the standard failover dance.
    let standby = Standby::start("127.0.0.1:0", &dir_s).expect("standby");
    let mut shipper = Shipper::new(
        Arc::clone(&primary),
        client(&standby.addr().to_string(), 0xE7A2),
    )
    .expect("shipper");
    let report = shipper.ship_once().expect("ship");
    assert_eq!(report.lag_bytes, 0, "fully shipped before the kill");
    drop(shipper);
    drop(server_p);
    drop(primary);
    let (promoted, recovery) = standby.promote(persist_cfg(&dir_p, 2)).expect("promote");
    assert!(recovery.wal_clean, "shipped WAL replays clean");
    let promoted = Arc::new(promoted);
    let server_n = serve(&promoted);

    // The promoted incarnation minted a disjoint handle space: every
    // era-1 handle is dead, not remapped onto the recovered streams.
    let mut probe = client(&server_n.addr().to_string(), 0xE7A3);
    for (name, h1) in names.iter().zip(&era1) {
        let h2 = probe.resolve(name).expect("era-2 resolve");
        assert_ne!(h2, *h1, "{name}: promoted node reused an era-1 handle");
    }

    // A client whose connection (and handle cache) outlives the next
    // era flip — a failover behind a stable address. No retry budget,
    // so recovery can only come from the breadth of the purge: the
    // first stale rejection must flush the WHOLE cache (the entire
    // handle era is dead), letting every other stream re-resolve by
    // name on its first attempt. A per-stream purge would leave the
    // other streams replaying dead handles and failing too.
    let mut stale = RetryingClient::with_policy(
        &server_n.addr().to_string(),
        ProtocolChoice::Auto,
        RetryPolicy {
            max_attempts: 1,
            ..fast_policy(0xE7A4)
        },
    );
    for (s, name) in names.iter().enumerate() {
        let got = stale.push_many(name, 1, &flat_batch(s, 20, 1, d)).expect("prime cache");
        assert_eq!(got, (1, 0), "{name}: cache-priming push");
    }
    stale.sync().expect("prime sync");
    // Era flip under the live connection: every stream re-registers in
    // a fresh handle range (unregister + register is exactly what a
    // recovery restart does to the handle space).
    for (name, spec) in names.iter().zip(specs) {
        promoted.unregister(name).expect("fence stream");
        promoted
            .register(name, d, AveragerSpec::parse(spec).expect("spec"))
            .expect("era-3 register");
    }

    // The rejected push itself has no retry budget left, so the stale
    // error surfaces — but it must take the whole cache with it.
    let err = stale
        .push_many(names[0], 1, &flat_batch(0, 21, 1, d))
        .expect_err("dead era-2 handle with max_attempts=1");
    assert!(
        err.to_string().contains("handle"),
        "structured stale-handle error, got: {err}"
    );
    // Every OTHER stream heals on its first attempt: its cache entry
    // was flushed by the rejection above.
    for (s, name) in names.iter().enumerate().skip(1) {
        let got = stale
            .push_many(name, 1, &flat_batch(s, 21, 1, d))
            .unwrap_or_else(|e| panic!("{name}: first attempt after the purge: {e}"));
        assert_eq!(got, (1, 0), "{name}: post-purge push");
    }
    // And the rejected stream itself heals on its next call.
    let got = stale
        .push_many(names[0], 1, &flat_batch(0, 21, 1, d))
        .expect("rejected stream self-heals");
    assert_eq!(got, (1, 0));
    stale.sync().expect("era-3 sync");
    for name in &names {
        assert_eq!(
            stale.snapshot(name).expect("era-3 snapshot").t,
            1,
            "{name}: exactly the post-flip push landed"
        );
    }
}
