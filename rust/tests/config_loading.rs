//! Integration: config files on disk → validated runnable configs.

use ata::config::{ExperimentFile, ServiceConfig};
use ata::linreg::run_experiment;

fn write_temp(name: &str, contents: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join("ata-config-tests");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join(name);
    std::fs::write(&path, contents).unwrap();
    path
}

#[test]
fn experiment_config_file_runs() {
    let path = write_temp(
        "exp.toml",
        r#"
# tiny smoke experiment
steps = 50
runs = 3
seed = 7
averagers = ["gea(c=0.5)", "awa3(c=0.5)", "true(c=0.5)"]

[sgd]
batch_size = 11
step_size = 0.2

[schedule]
kind = "stride"
stride = 10
"#,
    );
    let file = ExperimentFile::load(path.to_str().unwrap()).unwrap();
    assert_eq!(file.config.total_steps, 50);
    assert_eq!(file.config.runs, 3);
    let res = run_experiment(&file.config, None).unwrap();
    assert_eq!(res.curves.len(), 4); // 3 averagers + iterate
    assert_eq!(*res.steps.last().unwrap(), 50);
}

#[test]
fn service_config_file_loads() {
    let path = write_temp(
        "svc.toml",
        r#"
[service]
addr = "127.0.0.1:0"
shards = 2
queue_capacity = 32
backpressure = "reject"

[[stream]]
name = "layer0.weight"
dim = 16
averager = "awa3(c=0.5)"
"#,
    );
    let cfg = ServiceConfig::load(path.to_str().unwrap()).unwrap();
    assert_eq!(cfg.shards, 2);
    assert_eq!(cfg.streams.len(), 1);
    assert_eq!(cfg.streams[0].dim, 16);
    // And it boots a coordinator.
    let c = ata::coordinator::Coordinator::from_config(&cfg).unwrap();
    assert_eq!(c.stream_names(), vec!["layer0.weight".to_string()]);
}

#[test]
fn missing_file_is_a_clean_error() {
    let err = ExperimentFile::load("/nonexistent/nope.toml").unwrap_err();
    assert!(err.contains("cannot read"), "{err}");
    let err = ServiceConfig::load("/nonexistent/nope.toml").unwrap_err();
    assert!(err.contains("cannot read"), "{err}");
}

#[test]
fn malformed_file_is_a_clean_error() {
    let path = write_temp("bad.toml", "steps = [unterminated");
    let err = ExperimentFile::load(path.to_str().unwrap()).unwrap_err();
    assert!(err.contains("toml error"), "{err}");
}
