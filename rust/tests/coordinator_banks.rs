//! Planar stream banks, end to end: bank-vs-slot equivalence for every
//! banked spec, torn-free concurrent snapshots against a sequential
//! replay, and row recycling under register/unregister churn.

use ata::averagers::{AveragerSpec, WindowKind};
use ata::config::BackpressurePolicy;
use ata::coordinator::Coordinator;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread;

/// Every spec with a planar bank backend.
fn banked_specs() -> Vec<AveragerSpec> {
    vec![
        AveragerSpec::Exp { gamma: 0.9 },
        AveragerSpec::ExpK { k: 10 },
        AveragerSpec::Gea { c: 0.5 },
        AveragerSpec::Awa {
            window: WindowKind::Fixed { k: 7 },
            accumulators: 2,
        },
        AveragerSpec::Awa {
            window: WindowKind::Growing { c: 0.4 },
            accumulators: 2,
        },
        AveragerSpec::Awa {
            window: WindowKind::Fixed { k: 12 },
            accumulators: 3,
        },
        AveragerSpec::Awa {
            window: WindowKind::Growing { c: 0.5 },
            accumulators: 4,
        },
    ]
}

/// Deterministic sample stream: dim `d`, global index `i` (0-based).
fn sample(i: u64, d: usize) -> Vec<f64> {
    (0..d)
        .map(|dim| ((i * 31 + dim as u64 * 7 + 3) as f64 * 0.0137).sin() * 4.0)
        .collect()
}

#[test]
fn bank_vs_slot_equivalence_for_every_banked_spec() {
    // The acceptance property: identical content through a banking
    // coordinator, a banking-disabled coordinator, and a directly-driven
    // averager must agree to 1e-12 — per spec, with three streams per
    // bank and batch splits straddling flush/shift boundaries.
    let d = 3;
    let total = 400u64;
    for spec in banked_specs() {
        let banked = Coordinator::new(2, 256, BackpressurePolicy::Block);
        let slotted = Coordinator::with_banking(2, 256, BackpressurePolicy::Block, false);
        let mut directs = Vec::new();
        for s in 0..3 {
            let name = format!("s{s}");
            banked.register(&name, d, spec.clone()).unwrap();
            slotted.register(&name, d, spec.clone()).unwrap();
            directs.push(spec.build(d).unwrap());
        }
        // Interleave pushes across the three rows with varying batches.
        let batch_cycle = [1usize, 5, 2, 7, 13, 4, 30, 3, 11];
        let mut pos = [0u64; 3];
        let mut cycle = 0usize;
        while pos.iter().any(|&p| p < total) {
            for s in 0..3 {
                if pos[s] >= total {
                    continue;
                }
                let n = batch_cycle[cycle % batch_cycle.len()]
                    .min((total - pos[s]) as usize);
                cycle += 1;
                let mut flat = Vec::with_capacity(n * d);
                for k in 0..n {
                    // Distinct content per stream so rows cannot alias.
                    flat.extend(sample(pos[s] + k as u64 + 1000 * s as u64, d));
                }
                pos[s] += n as u64;
                let name = format!("s{s}");
                banked.push_many(&name, n, &flat).unwrap();
                slotted.push_many(&name, n, &flat).unwrap();
                directs[s].observe_many(&flat, n);
            }
        }
        banked.sync().unwrap();
        slotted.sync().unwrap();
        for s in 0..3 {
            let name = format!("s{s}");
            let a = banked.snapshot(&name).unwrap();
            let b = slotted.snapshot(&name).unwrap();
            assert_eq!(a.t, total, "{} {name}", spec.label());
            assert_eq!(b.t, total);
            assert_eq!(directs[s].t(), total);
            let want = directs[s].value().unwrap();
            let va = a.value.unwrap();
            let vb = b.value.unwrap();
            for i in 0..d {
                assert!(
                    (va[i] - want[i]).abs() < 1e-12,
                    "{} {name} dim {i}: banked {} vs direct {}",
                    spec.label(),
                    va[i],
                    want[i]
                );
                assert!(
                    (vb[i] - want[i]).abs() < 1e-12,
                    "{} {name} dim {i}: slot {} vs direct {}",
                    spec.label(),
                    vb[i],
                    want[i]
                );
            }
            assert!(
                (a.window_len - b.window_len).abs() < 1e-9,
                "{} window_len",
                spec.label()
            );
        }
    }
}

#[test]
fn banked_and_slot_specs_coexist() {
    // A bank-backed stream and a slot-fallback stream share the
    // coordinator; both must agree with direct replays.
    let d = 2;
    let c = Coordinator::new(3, 128, BackpressurePolicy::Block);
    let bank_spec = AveragerSpec::Awa {
        window: WindowKind::Growing { c: 0.5 },
        accumulators: 3,
    };
    let slot_spec = AveragerSpec::True {
        window: WindowKind::Fixed { k: 9 },
    };
    c.register("banked", d, bank_spec.clone()).unwrap();
    c.register("slotted", d, slot_spec.clone()).unwrap();
    let mut direct_bank = bank_spec.build(d).unwrap();
    let mut direct_slot = slot_spec.build(d).unwrap();
    for i in 0..300u64 {
        let x = sample(i, d);
        c.push("banked", x.clone()).unwrap();
        c.push("slotted", x.clone()).unwrap();
        direct_bank.observe(&x);
        direct_slot.observe(&x);
    }
    c.sync().unwrap();
    for (name, direct) in [("banked", &direct_bank), ("slotted", &direct_slot)] {
        let snap = c.snapshot(name).unwrap();
        assert_eq!(snap.t, 300);
        let got = snap.value.unwrap();
        let want = direct.value().unwrap();
        for i in 0..d {
            assert!((got[i] - want[i]).abs() < 1e-12, "{name} dim {i}");
        }
    }
}

#[test]
fn concurrent_snapshots_are_torn_free() {
    // The seqlock acceptance stress: hammer push_many from one thread
    // while two others snapshot; every snapshot must be internally
    // consistent — its value equals a sequential replay of exactly the
    // first `t` samples (to 1e-12; the recurrences are deterministic).
    let d = 4;
    let total: u64 = 30_000;
    let spec = AveragerSpec::Gea { c: 0.5 };
    let c = Arc::new(Coordinator::new(2, 256, BackpressurePolicy::Block));
    c.register("hot", d, spec.clone()).unwrap();
    let done = Arc::new(AtomicBool::new(false));

    let writer = {
        let c = Arc::clone(&c);
        let done = Arc::clone(&done);
        thread::spawn(move || {
            let mut sent = 0u64;
            let mut batch = 1usize;
            let mut flat = Vec::new();
            while sent < total {
                let n = batch.min((total - sent) as usize);
                flat.clear();
                for k in 0..n {
                    flat.extend(sample(sent + k as u64, d));
                }
                c.push_many("hot", n, &flat).unwrap();
                sent += n as u64;
                batch = batch % 17 + 1; // cycle 1..=17
            }
            c.sync().unwrap();
            done.store(true, Ordering::SeqCst);
        })
    };

    let readers: Vec<_> = (0..2)
        .map(|_| {
            let c = Arc::clone(&c);
            let done = Arc::clone(&done);
            thread::spawn(move || {
                let mut seen: Vec<(u64, Vec<f64>)> = Vec::new();
                let mut last_t = 0u64;
                while !done.load(Ordering::SeqCst) {
                    let snap = c.snapshot("hot").unwrap();
                    assert!(snap.t >= last_t, "published t went backwards");
                    last_t = snap.t;
                    if snap.t > 0 {
                        let v = snap.value.expect("value once t > 0");
                        if seen.last().map(|(t, _)| *t) != Some(snap.t) {
                            seen.push((snap.t, v.to_vec()));
                        }
                    }
                    thread::yield_now();
                }
                seen
            })
        })
        .collect();

    writer.join().unwrap();
    let mut observed: Vec<(u64, Vec<f64>)> = Vec::new();
    for r in readers {
        observed.extend(r.join().unwrap());
    }
    // Final state must be covered too.
    let final_snap = c.snapshot("hot").unwrap();
    assert_eq!(final_snap.t, total);
    observed.push((total, final_snap.value.unwrap().to_vec()));
    observed.sort_by_key(|(t, _)| *t);

    // One sequential replay checks every observed (t, value) pair.
    let mut replay = spec.build(d).unwrap();
    let mut idx = 0usize;
    for t in 1..=total {
        replay.observe(&sample(t - 1, d));
        while idx < observed.len() && observed[idx].0 == t {
            let want = replay.value().unwrap();
            let got = &observed[idx].1;
            for i in 0..d {
                assert!(
                    (got[i] - want[i]).abs() < 1e-12,
                    "torn snapshot at t={t} dim {i}: {} vs {}",
                    got[i],
                    want[i]
                );
            }
            idx += 1;
        }
    }
    assert_eq!(idx, observed.len(), "snapshot with impossible t observed");
    // On any non-degenerate scheduler the readers overlap the writer; do
    // not hard-fail on a starved machine, but keep the signal.
    if observed.len() < 5 {
        eprintln!(
            "note: only {} distinct snapshot points observed (slow machine?)",
            observed.len()
        );
    }
}

#[test]
fn unregister_recycles_rows_without_cross_talk() {
    // Rows freed by unregister are recycled for later registrations;
    // the recycled row must start clean and neighbours keep their state.
    let c = Coordinator::new(2, 64, BackpressurePolicy::Block);
    let spec = AveragerSpec::Awa {
        window: WindowKind::Fixed { k: 5 },
        accumulators: 2,
    };
    for i in 0..4 {
        c.register(&format!("s{i}"), 1, spec.clone()).unwrap();
        c.push_many(&format!("s{i}"), 3, &[i as f64; 3]).unwrap();
    }
    c.sync().unwrap();
    c.unregister("s1").unwrap();
    c.unregister("s2").unwrap();
    // New streams land on the recycled rows.
    c.register("n1", 1, spec.clone()).unwrap();
    c.register("n2", 1, spec.clone()).unwrap();
    assert_eq!(c.snapshot("n1").unwrap().t, 0);
    c.push_many("n1", 2, &[10.0, 20.0]).unwrap();
    c.sync().unwrap();
    let n1 = c.snapshot("n1").unwrap();
    assert_eq!(n1.t, 2);
    assert!((n1.value.unwrap()[0] - 15.0).abs() < 1e-12);
    // Survivors unaffected by the churn.
    for i in [0u64, 3] {
        let snap = c.snapshot(&format!("s{i}")).unwrap();
        assert_eq!(snap.t, 3);
        assert!((snap.value.unwrap()[0] - i as f64).abs() < 1e-12);
    }
}
