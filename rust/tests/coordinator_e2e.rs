//! Coordinator end-to-end: concurrent producers, ordered application,
//! anytime snapshots, and agreement with a directly-driven averager.

use ata::averagers::{AveragerSpec, WindowKind};
use ata::config::BackpressurePolicy;
use ata::coordinator::Coordinator;
use ata::rng::{GaussianSource, Xoshiro256};
use std::sync::Arc;
use std::thread;

#[test]
fn coordinator_agrees_with_direct_averager() {
    // One stream, one producer: the coordinator-mediated result must be
    // identical to driving the averager directly (same order, same math).
    let spec = AveragerSpec::Awa {
        window: WindowKind::Growing { c: 0.5 },
        accumulators: 3,
    };
    let c = Coordinator::new(2, 128, BackpressurePolicy::Block);
    c.register("w", 8, spec.clone()).unwrap();
    let mut direct = spec.build(8).unwrap();
    let mut g = GaussianSource::new(Xoshiro256::seed_from_u64(3));
    let mut x = vec![0.0; 8];
    for _ in 0..2000 {
        g.fill_standard(&mut x);
        direct.observe(&x);
        c.push("w", x.clone()).unwrap();
    }
    c.sync().unwrap();
    let snap = c.snapshot("w").unwrap();
    assert_eq!(snap.t, 2000);
    let want = direct.value().unwrap();
    let got = snap.value.unwrap();
    for i in 0..8 {
        assert!(
            (got[i] - want[i]).abs() < 1e-12,
            "dim {i}: {} vs {}",
            got[i],
            want[i]
        );
    }
}

#[test]
fn concurrent_producers_different_streams() {
    let c = Arc::new(Coordinator::new(4, 256, BackpressurePolicy::Block));
    let n_streams = 8;
    let per_stream = 500u64;
    for i in 0..n_streams {
        c.register(&format!("s{i}"), 4, AveragerSpec::Gea { c: 0.5 })
            .unwrap();
    }
    let mut handles = Vec::new();
    for i in 0..n_streams {
        let c = c.clone();
        handles.push(thread::spawn(move || {
            let name = format!("s{i}");
            for t in 1..=per_stream {
                c.push(&name, vec![t as f64; 4]).unwrap();
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    c.sync().unwrap();
    for i in 0..n_streams {
        let snap = c.snapshot(&format!("s{i}")).unwrap();
        assert_eq!(snap.t, per_stream, "stream {i}");
        let v = snap.value.unwrap();
        // Stream of 1..=500 averaged over a trailing window: strictly
        // positive, at most 500.
        assert!(v[0] > 0.0 && v[0] <= per_stream as f64);
        assert_eq!(v, vec![v[0]; 4]);
    }
}

#[test]
fn anytime_snapshots_while_producing() {
    // A reader thread snapshots concurrently with a writer; every
    // snapshot must be coherent (t monotone, value present once t > 0).
    let c = Arc::new(Coordinator::new(2, 64, BackpressurePolicy::Block));
    c.register("w", 2, AveragerSpec::Gea { c: 0.25 }).unwrap();
    let writer = {
        let c = c.clone();
        thread::spawn(move || {
            for t in 1..=5000u64 {
                c.push("w", vec![t as f64, -(t as f64)]).unwrap();
            }
        })
    };
    let reader = {
        let c = c.clone();
        thread::spawn(move || {
            let mut last_t = 0;
            let mut saw_mid_stream = false;
            for _ in 0..200 {
                let snap = c.snapshot("w").unwrap();
                assert!(snap.t >= last_t, "t went backwards");
                if snap.t > 0 {
                    let v = snap.value.expect("value once t>0");
                    assert!((v[0] + v[1]).abs() < 1e-9, "symmetric stream");
                }
                if snap.t > 0 && snap.t < 5000 {
                    saw_mid_stream = true;
                }
                last_t = snap.t;
                thread::yield_now();
            }
            saw_mid_stream
        })
    };
    writer.join().unwrap();
    let saw_mid = reader.join().unwrap();
    c.sync().unwrap();
    assert_eq!(c.snapshot("w").unwrap().t, 5000);
    // On any non-degenerate scheduler the reader overlaps the writer;
    // do not hard-fail if it did not, but keep the signal.
    if !saw_mid {
        eprintln!("note: reader never overlapped writer (slow machine?)");
    }
}

#[test]
fn stream_stats_account_for_everything() {
    let c = Coordinator::new(1, 64, BackpressurePolicy::Block);
    c.register("a", 1, AveragerSpec::Gea { c: 0.5 }).unwrap();
    c.register("b", 3, AveragerSpec::ExpK { k: 10 }).unwrap();
    for i in 0..50 {
        c.push("a", vec![i as f64]).unwrap();
    }
    for i in 0..20 {
        c.push("b", vec![i as f64; 3]).unwrap();
    }
    c.sync().unwrap();
    let stats = c.stream_stats();
    assert_eq!(stats.len(), 2);
    let a = stats.iter().find(|s| s.0 == "a").unwrap();
    let b = stats.iter().find(|s| s.0 == "b").unwrap();
    assert_eq!(a.1, 50);
    assert_eq!(b.1, 20);
    assert_eq!(a.3, 1); // GEA memory = d floats
    assert_eq!(b.3, 3); // EMA memory = d floats
    let exported = c.metrics().export();
    assert!(exported.get("counter.pushes_accepted").is_some());
}

#[test]
fn moment_tracker_over_coordinator_streams() {
    // The BatchNorm use case (paper conclusion): mean+var streams
    // tracked as two coordinator streams per layer.
    let c = Coordinator::new(2, 128, BackpressurePolicy::Block);
    c.register("bn.mean", 4, AveragerSpec::Gea { c: 0.5 }).unwrap();
    c.register("bn.sq", 4, AveragerSpec::Gea { c: 0.5 }).unwrap();
    let mut g = GaussianSource::new(Xoshiro256::seed_from_u64(11));
    let true_mean = [1.0, -2.0, 0.0, 5.0];
    let true_std = [0.5, 1.0, 2.0, 0.1];
    for _ in 0..20_000 {
        let x: Vec<f64> = (0..4)
            .map(|i| true_mean[i] + true_std[i] * g.next_gaussian())
            .collect();
        let sq: Vec<f64> = x.iter().map(|v| v * v).collect();
        c.push("bn.mean", x).unwrap();
        c.push("bn.sq", sq).unwrap();
    }
    c.sync().unwrap();
    let mean = c.snapshot("bn.mean").unwrap().value.unwrap();
    let sq = c.snapshot("bn.sq").unwrap().value.unwrap();
    for i in 0..4 {
        let var = sq[i] - mean[i] * mean[i];
        assert!(
            (mean[i] - true_mean[i]).abs() < 0.1,
            "mean[{i}]={}",
            mean[i]
        );
        let tv = true_std[i] * true_std[i];
        assert!(
            (var - tv).abs() < 0.15 * tv.max(0.1),
            "var[{i}]={var} want {tv}"
        );
    }
}
