//! Reproduction shape tests: small-scale versions of the paper's
//! acceptance criteria (DESIGN.md §5). The full-scale numbers live in
//! the benches; these run fast enough for `cargo test` and catch
//! regressions in the figure-defining behaviour.

use ata::linreg::{run_experiment, EvalSchedule, ExperimentConfig};
use ata::report;
use ata::util::pool::ThreadPool;

fn pool() -> ThreadPool {
    ThreadPool::with_default_size()
}

#[test]
fn fig3_c50_ordering_exp_worse_than_awa3_and_true() {
    // Paper Figure 3 right: at c = 0.5, exp (GEA) performs significantly
    // worse than true; awa3 is indistinguishable from true.
    let mut cfg = ExperimentConfig::figure3(0.5, 24);
    cfg.schedule = EvalSchedule::LogSpaced { points: 50 };
    let res = run_experiment(&cfg, Some(&pool())).unwrap();
    let gea_ratio = report::tail_ratio(&res, "gea", "true(", 0.2).unwrap();
    let awa3_ratio = report::tail_ratio(&res, "awa3", "true(", 0.2).unwrap();
    assert!(
        gea_ratio > 1.02,
        "GEA should lag true at c=0.5: ratio {gea_ratio}"
    );
    assert!(
        (awa3_ratio - 1.0).abs() < 0.05,
        "awa3 should match true at c=0.5: ratio {awa3_ratio}"
    );
    assert!(
        gea_ratio > awa3_ratio,
        "ordering must be exp > awa3 ({gea_ratio} vs {awa3_ratio})"
    );
}

#[test]
fn fig3_c25_all_methods_indistinguishable() {
    // Paper Figure 3 left: at c = 0.25 all proposed estimators closely
    // match the true average.
    let mut cfg = ExperimentConfig::figure3(0.25, 24);
    cfg.schedule = EvalSchedule::LogSpaced { points: 50 };
    let res = run_experiment(&cfg, Some(&pool())).unwrap();
    for label in ["gea", "awa2", "awa3"] {
        let ratio = report::tail_ratio(&res, label, "true(", 0.2).unwrap();
        assert!(
            (ratio - 1.0).abs() < 0.06,
            "{label} should match true at c=0.25: ratio {ratio}"
        );
    }
}

#[test]
fn fig2_expk_degrades_with_k_awa_does_not() {
    // Paper Figure 2: as k grows the EMA's use of old samples penalizes
    // it; AWA stays glued to the exact window. The effect lives in the
    // transient-bias regime (t ∈ [2k, 6k]) — see EXPERIMENTS.md
    // §Deviations for the stationary-tail autocorrelation caveat.
    let runs = 40;
    let sched = EvalSchedule::EveryStep;

    let mut cfg10 = ExperimentConfig::figure2(10, runs);
    cfg10.schedule = sched;
    let res10 = run_experiment(&cfg10, Some(&pool())).unwrap();
    let exp10 = report::range_ratio(&res10, "expk", "true(", 20, 60).unwrap();
    let awa10 = report::range_ratio(&res10, "awa2", "true(", 20, 60).unwrap();

    let mut cfg100 = ExperimentConfig::figure2(100, runs);
    cfg100.schedule = sched;
    let res100 = run_experiment(&cfg100, Some(&pool())).unwrap();
    let exp100 = report::range_ratio(&res100, "expk", "true(", 200, 600).unwrap();
    let awa100 = report::range_ratio(&res100, "awa2", "true(", 200, 600).unwrap();

    // k=10: everything within a few percent of true in its transient.
    assert!((exp10 - 1.0).abs() < 0.06, "expk@k=10 ratio {exp10}");
    assert!((awa10 - 1.0).abs() < 0.06, "awa@k=10 ratio {awa10}");
    // k=100: the EMA transient penalty is real and grows with k.
    assert!(
        exp100 > 1.02,
        "expk@k=100 must lag true in the transient: {exp100}"
    );
    assert!(
        exp100 > exp10 + 0.01,
        "expk penalty must grow with k: {exp10} -> {exp100}"
    );
    assert!(
        exp100 > awa100,
        "EMA transient degradation ({exp100}) must exceed AWA's ({awa100})"
    );
}

#[test]
fn raw_is_not_anytime_but_converges_to_true() {
    // raw has no average before T(1−c); from then on it is the exact
    // tail average, so its FINAL point matches true — but early in the
    // stream it reports the (much worse) raw iterate.
    let mut cfg = ExperimentConfig::figure3(0.5, 16);
    cfg.schedule = EvalSchedule::EveryStep;
    let res = run_experiment(&cfg, Some(&pool())).unwrap();
    let raw = res.curve("raw").unwrap();
    let truec = res.curve("true(").unwrap();
    let iterate = res.curve("iterate").unwrap();
    // Final: raw == true (both average exactly the last 500 samples).
    let rel = (raw.final_value() - truec.final_value()).abs() / truec.final_value();
    assert!(rel < 1e-9, "raw and true must coincide at T: rel {rel}");
    // Pre-start (t ≤ T(1−c) = 500): raw has NO average — it reports the
    // raw iterate at every eval point (the anytime limitation the
    // paper's methods remove); from t = 501 it starts averaging and
    // departs from the iterate.
    for (i, &t) in res.steps.iter().enumerate() {
        if t <= 500 {
            assert_eq!(raw.mean[i], iterate.mean[i], "raw = iterate at t={t}");
        }
    }
    let after = res.steps.iter().position(|&t| t == 600).unwrap();
    assert_ne!(
        raw.mean[after], iterate.mean[after],
        "raw must depart from the iterate once averaging starts"
    );
    // Meanwhile the anytime window is live the whole time.
    assert!(truec.mean.iter().all(|v| v.is_finite() && *v > 0.0));
}

#[test]
fn loglog_slopes_are_negative_for_all_averagers() {
    // Every averaged curve decays on the log-log plot over the tail.
    let mut cfg = ExperimentConfig::figure3(0.25, 12);
    cfg.schedule = EvalSchedule::LogSpaced { points: 60 };
    let res = run_experiment(&cfg, Some(&pool())).unwrap();
    for c in &res.curves {
        if c.label == "iterate" {
            continue;
        }
        let slope = report::loglog_slope(&res.steps, &c.mean, 0.5);
        assert!(
            slope < -0.3,
            "{}: slope {slope} should be decisively negative",
            c.label
        );
    }
}
