//! Fuzz-style property tests of the hand-written parsers: arbitrary
//! byte soup must never panic, and valid documents must round-trip.

use ata::config::toml::Toml;
use ata::testkit::{Gen, Runner};
use ata::util::json::Json;

/// Random "almost JSON" text: tokens stitched together with mutations.
fn arb_jsonish(g: &mut Gen) -> String {
    let tokens = [
        "{", "}", "[", "]", ",", ":", "\"", "null", "true", "false", "1",
        "-2.5", "1e9", "\\u0041", "\\", "\"key\"", " ", "\n", "é", "0x1",
        "NaN", "∞",
    ];
    let n = g.usize_range(0, 40);
    let mut s = String::new();
    for _ in 0..n {
        s.push_str(*g.choose(&tokens[..]));
    }
    s
}

/// Structured random JSON value (always valid).
fn arb_json(g: &mut Gen, depth: usize) -> Json {
    if depth == 0 {
        return match g.usize_range(0, 3) {
            0 => Json::Null,
            1 => Json::Bool(g.bool(0.5)),
            2 => Json::Num((g.gaussian() * 1e3 * 64.0).round() / 64.0),
            _ => Json::Str(arb_string(g)),
        };
    }
    match g.usize_range(0, 5) {
        0 => Json::Null,
        1 => Json::Bool(g.bool(0.5)),
        2 => Json::Num((g.gaussian() * 1e3 * 64.0).round() / 64.0),
        3 => Json::Str(arb_string(g)),
        4 => Json::Arr(
            (0..g.usize_range(0, 5))
                .map(|_| arb_json(g, depth - 1))
                .collect(),
        ),
        _ => {
            let mut m = std::collections::BTreeMap::new();
            for _ in 0..g.usize_range(0, 5) {
                m.insert(arb_string(g), arb_json(g, depth - 1));
            }
            Json::Obj(m)
        }
    }
}

fn arb_string(g: &mut Gen) -> String {
    let chars = ['a', 'Z', '0', ' ', '"', '\\', '\n', '\t', 'é', '→', '😀', '\u{7}'];
    (0..g.usize_range(0, 10)).map(|_| *g.choose(&chars[..])).collect()
}

#[test]
fn json_parser_never_panics_on_garbage() {
    Runner::new("json parse garbage", 0xF1).run(500, |g| {
        let text = arb_jsonish(g);
        let _ = Json::parse(&text); // must not panic; result irrelevant
        true
    });
}

#[test]
fn json_roundtrip_any_value() {
    Runner::new("json roundtrip", 0xF2).run(300, |g| {
        let v = arb_json(g, 4);
        let compact = Json::parse(&v.encode());
        let pretty = Json::parse(&v.encode_pretty());
        match (compact, pretty) {
            (Ok(a), Ok(b)) if a == v && b == v => Ok(()),
            (a, b) => Err(format!("roundtrip mismatch: {a:?} / {b:?} vs {v:?}")),
        }
    });
}

#[test]
fn toml_parser_never_panics_on_garbage() {
    Runner::new("toml parse garbage", 0xF3).run(500, |g| {
        let tokens = [
            "[", "]", "[[", "]]", "=", "\"", "'", "#", "a", "b.c", "1",
            "-2.5", "true", "{", "}", ",", "\n", " ", "\t", "é", "1_000",
        ];
        let n = g.usize_range(0, 40);
        let mut s = String::new();
        for _ in 0..n {
            s.push_str(*g.choose(&tokens[..]));
        }
        let _ = Toml::parse(&s); // must not panic
        true
    });
}

#[test]
fn toml_random_valid_docs_parse() {
    // Generate simple valid documents and check values survive.
    Runner::new("toml valid docs", 0xF4).run(200, |g| {
        let n_keys = g.usize_range(1, 8);
        let mut doc = String::new();
        let mut expected: Vec<(String, f64)> = Vec::new();
        for i in 0..n_keys {
            let key = format!("key_{i}");
            let val = (g.gaussian() * 100.0 * 64.0).round() / 64.0;
            doc.push_str(&format!("{key} = {val:?}\n"));
            expected.push((key, val));
        }
        let parsed = Toml::parse(&doc).map_err(|e| e.to_string())?;
        for (k, v) in expected {
            let got = parsed
                .get_path(&k)
                .and_then(Toml::as_f64)
                .ok_or_else(|| format!("missing {k}"))?;
            if (got - v).abs() > 1e-9 {
                return Err(format!("{k}: {got} != {v}"));
            }
        }
        Ok(())
    });
}

#[test]
fn wire_frames_survive_arbitrary_payloads() {
    use ata::coordinator::protocol::{read_frame, write_frame};
    Runner::new("frame roundtrip", 0xF5).run(200, |g| {
        let v = arb_json(g, 3);
        let mut buf = Vec::new();
        write_frame(&mut buf, &v).map_err(|e| e.to_string())?;
        let mut cursor = std::io::Cursor::new(buf);
        let back = read_frame(&mut cursor)
            .map_err(|e| e.to_string())?
            .ok_or("missing frame")?;
        if back != v {
            return Err(format!("{back:?} != {v:?}"));
        }
        Ok(())
    });
}

#[test]
fn truncated_frames_error_not_panic() {
    use ata::coordinator::protocol::{read_frame, write_frame};
    Runner::new("truncated frames", 0xF6).run(200, |g| {
        let v = arb_json(g, 2);
        let mut buf = Vec::new();
        write_frame(&mut buf, &v).map_err(|e| e.to_string())?;
        let cut = g.usize_range(0, buf.len().saturating_sub(1));
        buf.truncate(cut);
        let mut cursor = std::io::Cursor::new(buf);
        // Must be Ok(None) (clean EOF at len==0) or Err — never panic.
        let _ = read_frame(&mut cursor);
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// Durable-state codec fuzz: snapshot payloads and WAL bytes
// ---------------------------------------------------------------------------

use ata::averagers::{Averager, AveragerSpec, WindowKind};
use ata::persist::codec::{frame_state, unframe_state, Dec, Enc};

fn fuzz_specs() -> Vec<AveragerSpec> {
    vec![
        AveragerSpec::Exp { gamma: 0.9 },
        AveragerSpec::Gea { c: 0.5 },
        AveragerSpec::Awa {
            window: WindowKind::Fixed { k: 7 },
            accumulators: 2,
        },
        AveragerSpec::Awa {
            window: WindowKind::Growing { c: 0.4 },
            accumulators: 3,
        },
        AveragerSpec::True {
            window: WindowKind::Fixed { k: 5 },
        },
        AveragerSpec::Raw {
            c: 0.5,
            total_steps: 100,
        },
        AveragerSpec::Restart {
            window: WindowKind::Fixed { k: 4 },
        },
        AveragerSpec::Eh {
            window: WindowKind::Fixed { k: 30 },
            eps: 0.1,
        },
    ]
}

fn arb_bytes(g: &mut Gen, max: usize) -> Vec<u8> {
    let n = g.usize_range(0, max);
    (0..n).map(|_| (g.u64() & 0xFF) as u8).collect()
}

#[test]
fn state_codec_garbage_errors_never_panics() {
    Runner::new("state codec garbage", 0xF7).run(200, |g| {
        let bytes = arb_bytes(g, 256);
        // Framed envelope parse on random bytes.
        let _ = unframe_state(&bytes);
        // Raw payload import/merge into every estimator kind.
        for spec in fuzz_specs() {
            let mut a = spec.build(2)?;
            let _ = a.import_state(&mut Dec::new(&bytes));
            let _ = a.merge_state(&mut Dec::new(&bytes));
        }
        Ok(())
    });
}

#[test]
fn state_codec_truncated_and_bitflipped_exports_error_never_panic() {
    Runner::new("state codec truncate/bitflip", 0xF8).run(60, |g| {
        let d = g.usize_range(1, 3);
        for spec in fuzz_specs() {
            let mut a = spec.build(d)?;
            let n = g.usize_range(1, 40);
            let data: Vec<f64> = (0..n * d).map(|_| g.f64_range(-4.0, 4.0)).collect();
            a.observe_many(&data, n);
            let mut enc = Enc::new();
            a.export_state(&mut enc);
            let payload = enc.into_bytes();
            // Truncation at any proper prefix must error (the payload is
            // fully self-describing), never panic.
            let cut = g.usize_range(0, payload.len() - 1);
            let mut b = spec.build(d)?;
            if b.import_state(&mut Dec::new(&payload[..cut])).is_ok() {
                return Err(format!(
                    "{}: truncated payload (cut {cut}/{}) imported",
                    spec.label(),
                    payload.len()
                ));
            }
            // A bit flip anywhere in the FRAMED form fails the CRC.
            let mut framed = frame_state(&payload);
            let at = g.usize_range(0, framed.len() - 1);
            let bit = 1u8 << g.usize_range(0, 7);
            framed[at] ^= bit;
            if unframe_state(&framed).is_ok() {
                return Err(format!(
                    "{}: bit flip at byte {at} survived the CRC",
                    spec.label()
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn wal_and_snapshot_files_of_garbage_error_never_panic() {
    use ata::persist::{checkpoint, wal};
    use ata::testkit::temp_dir;
    let dir = temp_dir("fuzz-wal-garbage");
    Runner::new("wal/snapshot garbage files", 0xF9).run(60, |g| {
        let bytes = arb_bytes(g, 400);
        // A garbage WAL segment: replay must stop cleanly, not panic.
        std::fs::write(dir.join("seg-00000000.wal"), &bytes).map_err(|e| e.to_string())?;
        let mut n = 0u64;
        let summary = wal::replay(
            &dir,
            wal::WalPosition {
                segment: 0,
                offset: 0,
            },
            |_| n += 1,
        )?;
        if summary.records != n {
            return Err("replay miscounted".into());
        }
        // A garbage snapshot file: read must error or yield sections,
        // never panic.
        let snap = dir.join("snapshot-00000000.ata");
        std::fs::write(&snap, &bytes).map_err(|e| e.to_string())?;
        let _ = checkpoint::read_snapshot(&snap);
        Ok(())
    });
    let _ = std::fs::remove_dir_all(&dir);
}

// ---------------------------------------------------------------------------
// Protocol v2 binary frames and the hello handshake
// ---------------------------------------------------------------------------

use ata::coordinator::protocol::{self, MultiPushEntry, OpKind, Request, StreamRef, Wire};

fn arb_v2_request(g: &mut Gen) -> Request {
    let data = |g: &mut Gen, n: usize| -> Vec<f64> {
        (0..n).map(|_| g.f64_range(-1e6, 1e6)).collect()
    };
    match g.usize_range(0, 15) {
        0 => Request::Ping,
        1 => Request::Register {
            stream: format!("s{}", g.usize_range(0, 1000)),
            dim: g.usize_range(1, 64),
            spec: "gea(c=0.5)".into(),
        },
        2 => Request::Resolve {
            stream: format!("s{}", g.usize_range(0, 1000)),
        },
        3 => {
            let n = g.usize_range(0, 16);
            Request::Push {
                stream: StreamRef::Handle(g.u64()),
                data: data(g, n),
            }
        }
        4 => {
            let count = g.usize_range(0, 8);
            let len = g.usize_range(0, 32);
            Request::PushMany {
                stream: StreamRef::Handle(g.u64()),
                count,
                data: data(g, len),
            }
        }
        5 => {
            let n = g.usize_range(0, 5);
            Request::MultiPush {
                entries: (0..n)
                    .map(|_| {
                        let len = g.usize_range(0, 12);
                        MultiPushEntry {
                            handle: g.u64(),
                            count: g.usize_range(0, 6),
                            data: data(g, len),
                        }
                    })
                    .collect(),
            }
        }
        6 => Request::Snapshot {
            stream: StreamRef::Handle(g.u64()),
        },
        7 => Request::Sync,
        8 => Request::Restore {
            stream: StreamRef::Handle(g.u64()),
            state: (0..g.usize_range(0, 64))
                .map(|_| (g.u64() & 0xFF) as u8)
                .collect(),
        },
        9 => Request::Query {
            prefix: format!("p{}", g.usize_range(0, 50)),
            z: g.f64_range(0.0, 5.0),
            top_k: g.u64() & 0xFF,
            aggregate: g.bool(0.5),
        },
        10 => Request::MultiSnapshot {
            streams: (0..g.usize_range(0, 8))
                .map(|_| StreamRef::Handle(g.u64()))
                .collect(),
        },
        11 => Request::Introspect,
        12 => Request::MetricsProm,
        13 => Request::WalShip {
            shard: (g.u64() & 0xFFFF) as u16,
            segment: g.u64(),
            offset: g.u64(),
            done: g.bool(0.5),
            bytes: arb_bytes(g, 96),
        },
        14 => Request::ClusterHello {
            ring: arb_bytes(g, 96),
        },
        _ => Request::ExportState {
            stream: StreamRef::Handle(g.u64()),
        },
    }
}

#[test]
fn v2_decoder_never_panics_on_garbage() {
    Runner::new("v2 decode garbage", 0xFA).run(500, |g| {
        let bytes = arb_bytes(g, 300);
        // Request and response decoders on byte soup: Err, never panic,
        // never a giant allocation (Dec bounds-checks before allocating).
        let _ = protocol::decode_request(Wire::V2Binary, &bytes);
        for kind in [
            OpKind::Ping,
            OpKind::PushMany,
            OpKind::MultiPush,
            OpKind::Snapshot,
            OpKind::List,
            OpKind::ExportState,
            OpKind::Query,
            OpKind::MultiSnapshot,
            OpKind::Introspect,
            OpKind::MetricsProm,
            OpKind::WalShip,
            OpKind::ClusterHello,
        ] {
            let _ = protocol::decode_response(Wire::V2Binary, kind, &bytes);
        }
        true
    });
}

#[test]
fn v2_analytics_responses_roundtrip_and_mutations_never_panic() {
    use ata::coordinator::protocol::{Response, StatEntry, StatOutcome};
    Runner::new("v2 analytics response roundtrip", 0xFE).run(200, |g| {
        let entry = |g: &mut Gen| -> StatEntry {
            let d = g.usize_range(0, 5);
            StatEntry {
                stream: format!("s{}", g.usize_range(0, 100)),
                t: g.u64() & 0xFFFF,
                effective_window: g.f64_range(0.0, 1e4),
                ess: g.f64_range(0.0, 1e4),
                mean: (0..d).map(|_| g.f64_range(-1e3, 1e3)).collect(),
                variance: (0..d).map(|_| g.f64_range(0.0, 1e3)).collect(),
                band: (0..d).map(|_| g.f64_range(0.0, 1e2)).collect(),
            }
        };
        let n = g.usize_range(0, 4);
        let resp = if g.bool(0.5) {
            Response::QueryStats {
                stats: (0..n).map(|_| entry(g)).collect(),
                aggregate: if g.bool(0.5) { Some(entry(g)) } else { None },
                aggregated: g.u64() & 0xFF,
            }
        } else {
            Response::MultiStats {
                stats: (0..n)
                    .map(|_| {
                        if g.bool(0.7) {
                            StatOutcome::Stat(entry(g))
                        } else {
                            StatOutcome::Missing(format!("no stream with handle {}", g.u64()))
                        }
                    })
                    .collect(),
            }
        };
        let kind = match &resp {
            Response::QueryStats { .. } => OpKind::Query,
            _ => OpKind::MultiSnapshot,
        };
        let mut buf = Vec::new();
        let trace = g.u64();
        protocol::encode_response(Wire::V2Binary, 7, trace, &resp, &mut buf)
            .map_err(|e| e.to_string())?;
        let (seq, got_trace, back) =
            protocol::decode_response(Wire::V2Binary, kind, &buf).map_err(|e| e.to_string())?;
        if seq != 7 || got_trace != trace || back != resp {
            return Err(format!("roundtrip mismatch: {back:?} vs {resp:?}"));
        }
        // Truncations and bit flips error, never panic.
        let mut mutated = buf.clone();
        match g.usize_range(0, 2) {
            0 => {
                let cut = g.usize_range(0, mutated.len());
                mutated.truncate(cut);
            }
            _ => {
                if !mutated.is_empty() {
                    let at = g.usize_range(0, mutated.len() - 1);
                    mutated[at] ^= 1 << g.usize_range(0, 7);
                }
            }
        }
        let _ = protocol::decode_response(Wire::V2Binary, kind, &mutated);
        Ok(())
    });
}

#[test]
fn v2_request_roundtrip_and_mutations_never_panic() {
    Runner::new("v2 request roundtrip", 0xFB).run(300, |g| {
        let req = arb_v2_request(g);
        let seq = g.u64();
        let trace = g.u64();
        let mut buf = Vec::new();
        protocol::encode_request(Wire::V2Binary, seq, trace, &req, &mut buf)
            .map_err(|e| e.to_string())?;
        let (got_seq, got_trace, back) =
            protocol::decode_request(Wire::V2Binary, &buf).map_err(|e| e.to_string())?;
        if got_seq != seq || got_trace != trace || back != req {
            return Err(format!("roundtrip mismatch: {back:?} vs {req:?}"));
        }
        // A random mutation of a valid frame must decode-or-error,
        // never panic (truncation, bit flips, trailing bytes).
        let mut mutated = buf.clone();
        match g.usize_range(0, 3) {
            0 => {
                let cut = g.usize_range(0, mutated.len());
                mutated.truncate(cut);
            }
            1 => {
                if !mutated.is_empty() {
                    let at = g.usize_range(0, mutated.len() - 1);
                    mutated[at] ^= 1 << g.usize_range(0, 7);
                }
            }
            _ => mutated.push((g.u64() & 0xFF) as u8),
        }
        let _ = protocol::decode_request(Wire::V2Binary, &mutated);
        Ok(())
    });
}

#[test]
fn handshake_parser_never_panics_and_only_accepts_hellos() {
    Runner::new("hello handshake fuzz", 0xFC).run(500, |g| {
        // Byte soup is never a hello…
        let bytes = arb_bytes(g, 16);
        let parsed = protocol::parse_hello(&bytes);
        if let Some(v) = parsed {
            // …unless it structurally IS one: 6 bytes starting "ATAH".
            if bytes.len() != 6 || &bytes[..4] != b"ATAH" {
                return Err(format!("accepted a non-hello: {bytes:?} -> {v}"));
            }
        }
        // Valid hellos always parse back to their version…
        let version = (g.u64() & 0xFFFF) as u16;
        let hello = protocol::hello_frame(version);
        if protocol::parse_hello(&hello) != Some(version) {
            return Err("hello roundtrip failed".into());
        }
        // …and any single-byte corruption either still parses (payload
        // version flip) or is cleanly rejected.
        let mut bad = hello.clone();
        let at = g.usize_range(0, bad.len() - 1);
        bad[at] ^= 1 << g.usize_range(0, 7);
        let _ = protocol::parse_hello(&bad);
        Ok(())
    });
}

#[test]
fn v2_frames_over_a_live_connection_never_kill_the_server() {
    use ata::config::BackpressurePolicy;
    use ata::coordinator::{Coordinator, Server};
    use std::io::Write;
    use std::sync::Arc;
    // End-to-end fuzz: a handshaken connection fed random frames must
    // always get a structured response (or a clean close on transport
    // abuse), and the server must keep serving other clients.
    let c = Arc::new(Coordinator::new(1, 64, BackpressurePolicy::Block));
    let server = Server::start("127.0.0.1:0", c, 2).expect("server");
    let addr = server.addr().to_string();
    Runner::new("live v2 garbage frames", 0xFD).run(40, |g| {
        let mut s = std::net::TcpStream::connect(&addr).map_err(|e| e.to_string())?;
        protocol::write_frame_bytes(&mut s, &protocol::hello_frame(protocol::WIRE_V2))
            .map_err(|e| e.to_string())?;
        let mut buf = Vec::new();
        protocol::read_frame_into(&mut s, &mut buf)
            .map_err(|e| e.to_string())?
            .ok_or("no hello ack")?;
        for _ in 0..g.usize_range(1, 6) {
            let garbage = arb_bytes(g, 64);
            protocol::write_frame_bytes(&mut s, &garbage).map_err(|e| e.to_string())?;
            // Every garbage frame is answered (framing stays intact).
            protocol::read_frame_into(&mut s, &mut buf)
                .map_err(|e| e.to_string())?
                .ok_or("server dropped a garbage frame without answering")?;
        }
        // Raw non-frame bytes (a torn length prefix) may close the
        // connection — but must not take the server down.
        let _ = s.write_all(&[0xFF]);
        drop(s);
        let mut check = std::net::TcpStream::connect(&addr).map_err(|e| e.to_string())?;
        protocol::write_frame_bytes(&mut check, &protocol::hello_frame(protocol::WIRE_V2))
            .map_err(|e| e.to_string())?;
        protocol::read_frame_into(&mut check, &mut buf)
            .map_err(|e| e.to_string())?
            .ok_or("server gone after garbage session")?;
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// Observability codecs: flight-recorder events and the introspect report
// ---------------------------------------------------------------------------

use ata::obs::introspect::{BankReport, IntrospectReport, ShardReport, StreamReport};
use ata::obs::recorder::{Event, EventKind, EVENT_ENCODED_LEN};
use ata::obs::SpanRecord;

fn arb_event(g: &mut Gen) -> Event {
    let kinds = [
        EventKind::Push,
        EventKind::Drop,
        EventKind::Quarantine,
        EventKind::Poison,
        EventKind::Overload,
        EventKind::WalRotation,
        EventKind::Checkpoint,
        EventKind::WalShip,
        EventKind::RingUpdate,
    ];
    Event {
        kind: *g.choose(&kinds[..]),
        shard: (g.u64() & 0xFFFF) as u16,
        trace_id: g.u64(),
        handle: g.u64(),
        arg: g.u64(),
        at_nanos: g.u64(),
    }
}

/// Count-like report fields ride the JSON codec as plain numbers, so
/// their roundtrip contract only covers the f64-exact integer domain
/// (< 2^53) — ids (`trace_id`, `handle`) travel as decimal strings and
/// keep full u64 range. The generator mirrors that split.
const MAX_SAFE_COUNT: u64 = (1 << 53) - 1;

fn arb_introspect(g: &mut Gen) -> IntrospectReport {
    IntrospectReport {
        sample_per_mille: (g.u64() % 1001) as u32,
        wal_skipped_tails: g.u64() & MAX_SAFE_COUNT,
        shards: (0..g.usize_range(0, 4))
            .map(|i| ShardReport {
                shard: i as u16,
                queue_depth: g.u64() & 0xFFFF,
                worker_starts: g.u64() & 0xFF,
                wal_segment: g.u64() & MAX_SAFE_COUNT,
                wal_offset: g.u64() & MAX_SAFE_COUNT,
                wal_replay_segment: g.u64() & MAX_SAFE_COUNT,
                wal_replay_offset: g.u64() & MAX_SAFE_COUNT,
                events_recorded: g.u64() & MAX_SAFE_COUNT,
            })
            .collect(),
        banks: (0..g.usize_range(0, 3))
            .map(|i| BankReport {
                index: i as u64,
                dim: g.u64() & 0xFFF,
                rows: g.u64() & 0xFFFF,
                row_floats: g.u64() & MAX_SAFE_COUNT,
            })
            .collect(),
        streams: (0..g.usize_range(0, 4))
            .map(|_| StreamReport {
                name: arb_string(g),
                handle: g.u64(),
                dropped: g.u64() & MAX_SAFE_COUNT,
                strikes: g.u64() & 0xFF,
                poisoned: g.bool(0.3),
            })
            .collect(),
        events: (0..g.usize_range(0, 5))
            .map(|_| {
                let mut e = arb_event(g);
                e.arg &= MAX_SAFE_COUNT;
                e.at_nanos &= MAX_SAFE_COUNT;
                e
            })
            .collect(),
        spans: (0..g.usize_range(0, 3))
            .map(|_| SpanRecord {
                trace_id: g.u64(),
                stage_ns: [
                    g.u64() & MAX_SAFE_COUNT,
                    g.u64() & MAX_SAFE_COUNT,
                    g.u64() & MAX_SAFE_COUNT,
                    g.u64() & MAX_SAFE_COUNT,
                    g.u64() & MAX_SAFE_COUNT,
                    g.u64() & MAX_SAFE_COUNT,
                ],
            })
            .collect(),
    }
}

#[test]
fn flight_event_codec_roundtrips_and_survives_garbage() {
    Runner::new("flight event codec fuzz", 0xE1).run(300, |g| {
        // Valid events round-trip at the documented encoded length.
        let ev = arb_event(g);
        let mut enc = Enc::new();
        ev.encode(&mut enc);
        let bytes = enc.into_bytes();
        if bytes.len() != EVENT_ENCODED_LEN {
            return Err(format!("encoded {} bytes, expected {EVENT_ENCODED_LEN}", bytes.len()));
        }
        let back = Event::decode(&mut Dec::new(&bytes)).map_err(|e| e.to_string())?;
        if back != ev {
            return Err(format!("{back:?} != {ev:?}"));
        }
        // Truncations error (never panic) — the decoder bounds-checks.
        let cut = g.usize_range(0, bytes.len() - 1);
        if Event::decode(&mut Dec::new(&bytes[..cut])).is_ok() {
            return Err(format!("truncated event (cut {cut}) decoded"));
        }
        // A corrupted kind tag is a structured error, not a panic, and
        // arbitrary byte soup never panics either.
        let mut bad = bytes.clone();
        bad[0] = (g.u64() & 0xFF) as u8;
        let _ = Event::decode(&mut Dec::new(&bad));
        let soup = arb_bytes(g, 64);
        let _ = Event::decode(&mut Dec::new(&soup));
        Ok(())
    });
}

#[test]
fn introspect_report_codecs_roundtrip_and_survive_mutations() {
    Runner::new("introspect codec fuzz", 0xE2).run(120, |g| {
        let report = arb_introspect(g);
        // Binary codec (the v2 wire form).
        let mut enc = Enc::new();
        report.encode(&mut enc);
        let bytes = enc.into_bytes();
        let back =
            IntrospectReport::decode(&mut Dec::new(&bytes)).map_err(|e| e.to_string())?;
        if back != report {
            return Err("binary roundtrip mismatch".into());
        }
        // JSON codec (the v1 envelope form) — wide u64s must survive.
        let back = IntrospectReport::from_json(&report.to_json()).map_err(|e| e.to_string())?;
        if back != report {
            return Err("json roundtrip mismatch".into());
        }
        // Mutations of the binary form error-or-decode, never panic.
        let mut mutated = bytes.clone();
        match g.usize_range(0, 2) {
            0 => {
                let cut = g.usize_range(0, mutated.len());
                mutated.truncate(cut);
            }
            _ => {
                if !mutated.is_empty() {
                    let at = g.usize_range(0, mutated.len() - 1);
                    mutated[at] ^= 1 << g.usize_range(0, 7);
                }
            }
        }
        let _ = IntrospectReport::decode(&mut Dec::new(&mutated));
        // Byte soup through the whole response decoder for this op.
        let soup = arb_bytes(g, 200);
        let _ = protocol::decode_response(Wire::V2Binary, OpKind::Introspect, &soup);
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// Cluster ring codec: the placement map that rides `cluster_hello`
// ---------------------------------------------------------------------------

use ata::cluster::ring::{HashRing, RING_FORMAT_VERSION, RING_MAGIC};

fn arb_ring(g: &mut Gen) -> HashRing {
    let mut ring = HashRing::new(g.usize_range(1, 8) as u32);
    let n_nodes = g.usize_range(1, 5);
    for i in 0..n_nodes {
        ring.add_node(&format!("node-{i}"), &format!("10.0.0.{i}:741{i}"))
            .expect("unique id");
    }
    for p in 0..g.usize_range(0, 4) {
        let target = format!("node-{}", g.usize_range(0, n_nodes - 1));
        ring.pin(&format!("pinned/s{p}"), &target).expect("pin");
    }
    if g.bool(0.3) {
        // Exercise the failover primitive in the encoded form too.
        ring.replace_addr("node-0", "10.9.9.9:7499").expect("repoint");
    }
    ring
}

#[test]
fn ring_codec_roundtrips_and_mutations_error_never_panic() {
    Runner::new("ring codec fuzz", 0xE3).run(200, |g| {
        let ring = arb_ring(g);
        let bytes = ring.encode();
        let back = HashRing::decode(&bytes).map_err(|e| e.to_string())?;
        // The encoding is canonical: re-encoding the decoded ring must
        // reproduce the exact bytes (this is what version gossip
        // compares and ships).
        if back.encode() != bytes {
            return Err("ring re-encode is not canonical".into());
        }
        if back.version() != ring.version() {
            return Err(format!("version {} != {}", back.version(), ring.version()));
        }
        // Placement survives the trip: pins and hashed streams alike.
        for s in ["a", "stream/b", "pinned/s0", "é😀"] {
            let want = ring.route(s).map(|n| n.id.clone());
            let got = back.route(s).map(|n| n.id.clone());
            if want != got {
                return Err(format!("route('{s}') moved across the codec: {want:?} vs {got:?}"));
            }
        }
        // Truncation at any proper prefix errors, never panics.
        let cut = g.usize_range(0, bytes.len() - 1);
        if HashRing::decode(&bytes[..cut]).is_ok() {
            return Err(format!("truncated ring (cut {cut}/{}) decoded", bytes.len()));
        }
        // Single-byte corruption decodes-or-errors, never panics, and
        // never produces a giant allocation (hostile counts are checked
        // against the bytes actually remaining).
        let mut bad = bytes.clone();
        let at = g.usize_range(0, bad.len() - 1);
        bad[at] ^= 1 << g.usize_range(0, 7);
        let _ = HashRing::decode(&bad);
        Ok(())
    });
}

#[test]
fn ring_decode_rejects_garbage_and_version_mismatch() {
    Runner::new("ring hostile decode", 0xE4).run(300, |g| {
        // Byte soup never panics; without the magic it must error.
        let soup = arb_bytes(g, 200);
        if !soup.starts_with(RING_MAGIC) && HashRing::decode(&soup).is_ok() {
            return Err(format!("decoded {} bytes of soup without magic", soup.len()));
        }
        // A frame from a "future" peer: right magic, newer format
        // version. The decoder must refuse it with a structured error
        // (mixed-version clusters fail loud, not by misparsing).
        let mut enc = Enc::new();
        for &b in RING_MAGIC {
            enc.put_u8(b);
        }
        let future = RING_FORMAT_VERSION + 1 + (g.u64() & 0xFF) as u16;
        enc.put_u16(future);
        let mut frame = enc.into_bytes();
        frame.extend(arb_bytes(g, 64));
        match HashRing::decode(&frame) {
            Ok(_) => Err("decoded a future format version".into()),
            Err(e) if e.contains("format version") => Ok(()),
            Err(e) => Err(format!("wrong refusal for version mismatch: {e}")),
        }
    });
}

// ---------------------------------------------------------------------------
// Chaos harness decision streams (the panic/reset fault kinds ride the
// fuzz smoke too: random rates, fixed seeds, bounded decisions)
// ---------------------------------------------------------------------------

#[test]
fn chaos_fault_decisions_are_deterministic_bounded_and_scoped() {
    use ata::testkit::chaos;
    // Chaos state is process-global: serialize with every other
    // chaos-arming test in this binary.
    let _guard = chaos::test_mutex().lock().unwrap_or_else(|e| e.into_inner());
    Runner::new("chaos decision streams", 0xC4A5).run(60, |g| {
        let seed = g.u64();
        let torn_p = g.usize_range(0, 1001) as u16;
        let reset_p = g.usize_range(0, 1001) as u16;
        let plan = chaos::ChaosPlan {
            seed,
            torn_write_per_mille: torn_p,
            conn_reset_per_mille: reset_p,
            ..Default::default()
        };
        let draw = |n: usize| -> (Vec<Option<usize>>, Vec<bool>) {
            chaos::arm(plan);
            let torn: Vec<Option<usize>> = (0..n).map(|_| chaos::torn_write(64)).collect();
            let resets: Vec<bool> = (0..n).map(|_| chaos::conn_reset()).collect();
            (torn, resets)
        };
        let n = g.usize_range(1, 200);
        let (torn_a, resets_a) = draw(n);
        // Bounded: a tear is always a strict prefix of the frame.
        for t in torn_a.iter().flatten() {
            if *t >= 64 {
                return Err(format!("tear offset {t} >= frame len 64"));
            }
        }
        // Rate endpoints are exact, not probabilistic.
        let fired = torn_a.iter().filter(|t| t.is_some()).count();
        match torn_p {
            0 if fired != 0 => return Err("p=0 fired".into()),
            1000 if fired != n => return Err("p=1000 missed".into()),
            _ => {}
        }
        if chaos::injected(chaos::Site::TornWrite) != fired as u64 {
            return Err("injected counter disagrees with observed fires".into());
        }
        // Deterministic: re-arming the identical plan replays the
        // identical decision stream, fire for fire.
        let (torn_b, resets_b) = draw(n);
        if torn_a != torn_b || resets_a != resets_b {
            return Err(format!("decision stream not reproducible (seed {seed:#x})"));
        }
        // Scoped worker panics: a non-matching stream never panics, a
        // matching one at p=1000 always does, and disarm silences all.
        chaos::arm(chaos::ChaosPlan {
            seed,
            panic_per_mille: 1000,
            panic_prefix: Some("fz/"),
            ..Default::default()
        });
        chaos::maybe_worker_panic("other/stream"); // must not panic
        let hit = std::panic::catch_unwind(|| chaos::maybe_worker_panic("fz/stream"));
        if hit.is_ok() {
            return Err("prefix-matched panic site did not fire at p=1000".into());
        }
        if chaos::injected(chaos::Site::WorkerPanic) != 1 {
            return Err("panic injection not counted".into());
        }
        chaos::disarm();
        if chaos::torn_write(64).is_some() || chaos::conn_reset() {
            return Err("disarmed hooks still firing".into());
        }
        Ok(())
    });
    chaos::disarm();
}
