//! Fuzz-style property tests of the hand-written parsers: arbitrary
//! byte soup must never panic, and valid documents must round-trip.

use ata::config::toml::Toml;
use ata::testkit::{Gen, Runner};
use ata::util::json::Json;

/// Random "almost JSON" text: tokens stitched together with mutations.
fn arb_jsonish(g: &mut Gen) -> String {
    let tokens = [
        "{", "}", "[", "]", ",", ":", "\"", "null", "true", "false", "1",
        "-2.5", "1e9", "\\u0041", "\\", "\"key\"", " ", "\n", "é", "0x1",
        "NaN", "∞",
    ];
    let n = g.usize_range(0, 40);
    let mut s = String::new();
    for _ in 0..n {
        s.push_str(*g.choose(&tokens[..]));
    }
    s
}

/// Structured random JSON value (always valid).
fn arb_json(g: &mut Gen, depth: usize) -> Json {
    if depth == 0 {
        return match g.usize_range(0, 3) {
            0 => Json::Null,
            1 => Json::Bool(g.bool(0.5)),
            2 => Json::Num((g.gaussian() * 1e3 * 64.0).round() / 64.0),
            _ => Json::Str(arb_string(g)),
        };
    }
    match g.usize_range(0, 5) {
        0 => Json::Null,
        1 => Json::Bool(g.bool(0.5)),
        2 => Json::Num((g.gaussian() * 1e3 * 64.0).round() / 64.0),
        3 => Json::Str(arb_string(g)),
        4 => Json::Arr(
            (0..g.usize_range(0, 5))
                .map(|_| arb_json(g, depth - 1))
                .collect(),
        ),
        _ => {
            let mut m = std::collections::BTreeMap::new();
            for _ in 0..g.usize_range(0, 5) {
                m.insert(arb_string(g), arb_json(g, depth - 1));
            }
            Json::Obj(m)
        }
    }
}

fn arb_string(g: &mut Gen) -> String {
    let chars = ['a', 'Z', '0', ' ', '"', '\\', '\n', '\t', 'é', '→', '😀', '\u{7}'];
    (0..g.usize_range(0, 10)).map(|_| *g.choose(&chars[..])).collect()
}

#[test]
fn json_parser_never_panics_on_garbage() {
    Runner::new("json parse garbage", 0xF1).run(500, |g| {
        let text = arb_jsonish(g);
        let _ = Json::parse(&text); // must not panic; result irrelevant
        true
    });
}

#[test]
fn json_roundtrip_any_value() {
    Runner::new("json roundtrip", 0xF2).run(300, |g| {
        let v = arb_json(g, 4);
        let compact = Json::parse(&v.encode());
        let pretty = Json::parse(&v.encode_pretty());
        match (compact, pretty) {
            (Ok(a), Ok(b)) if a == v && b == v => Ok(()),
            (a, b) => Err(format!("roundtrip mismatch: {a:?} / {b:?} vs {v:?}")),
        }
    });
}

#[test]
fn toml_parser_never_panics_on_garbage() {
    Runner::new("toml parse garbage", 0xF3).run(500, |g| {
        let tokens = [
            "[", "]", "[[", "]]", "=", "\"", "'", "#", "a", "b.c", "1",
            "-2.5", "true", "{", "}", ",", "\n", " ", "\t", "é", "1_000",
        ];
        let n = g.usize_range(0, 40);
        let mut s = String::new();
        for _ in 0..n {
            s.push_str(*g.choose(&tokens[..]));
        }
        let _ = Toml::parse(&s); // must not panic
        true
    });
}

#[test]
fn toml_random_valid_docs_parse() {
    // Generate simple valid documents and check values survive.
    Runner::new("toml valid docs", 0xF4).run(200, |g| {
        let n_keys = g.usize_range(1, 8);
        let mut doc = String::new();
        let mut expected: Vec<(String, f64)> = Vec::new();
        for i in 0..n_keys {
            let key = format!("key_{i}");
            let val = (g.gaussian() * 100.0 * 64.0).round() / 64.0;
            doc.push_str(&format!("{key} = {val:?}\n"));
            expected.push((key, val));
        }
        let parsed = Toml::parse(&doc).map_err(|e| e.to_string())?;
        for (k, v) in expected {
            let got = parsed
                .get_path(&k)
                .and_then(Toml::as_f64)
                .ok_or_else(|| format!("missing {k}"))?;
            if (got - v).abs() > 1e-9 {
                return Err(format!("{k}: {got} != {v}"));
            }
        }
        Ok(())
    });
}

#[test]
fn wire_frames_survive_arbitrary_payloads() {
    use ata::coordinator::protocol::{read_frame, write_frame};
    Runner::new("frame roundtrip", 0xF5).run(200, |g| {
        let v = arb_json(g, 3);
        let mut buf = Vec::new();
        write_frame(&mut buf, &v).map_err(|e| e.to_string())?;
        let mut cursor = std::io::Cursor::new(buf);
        let back = read_frame(&mut cursor)
            .map_err(|e| e.to_string())?
            .ok_or("missing frame")?;
        if back != v {
            return Err(format!("{back:?} != {v:?}"));
        }
        Ok(())
    });
}

#[test]
fn truncated_frames_error_not_panic() {
    use ata::coordinator::protocol::{read_frame, write_frame};
    Runner::new("truncated frames", 0xF6).run(200, |g| {
        let v = arb_json(g, 2);
        let mut buf = Vec::new();
        write_frame(&mut buf, &v).map_err(|e| e.to_string())?;
        let cut = g.usize_range(0, buf.len().saturating_sub(1));
        buf.truncate(cut);
        let mut cursor = std::io::Cursor::new(buf);
        // Must be Ok(None) (clean EOF at len==0) or Err — never panic.
        let _ = read_frame(&mut cursor);
        Ok(())
    });
}
