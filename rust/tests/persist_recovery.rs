//! Durability integration tests: snapshot→restore equivalence for every
//! estimator (slot and banked), state merges, checkpoint + WAL crash
//! recovery, and WAL-truncation fault injection.

use ata::averagers::{Averager, AveragerSpec, WindowKind};
use ata::config::{BackpressurePolicy, PersistConfig, ServiceConfig};
use ata::coordinator::Coordinator;
use ata::persist::codec::{Dec, Enc};
use ata::persist::wal;
use ata::testkit::{temp_dir, Runner};
use std::path::Path;

/// Every `AveragerSpec` variant (both window kinds where applicable) —
/// a mix of planar-bank and slot backings.
fn all_specs() -> Vec<AveragerSpec> {
    vec![
        AveragerSpec::Exp { gamma: 0.9 },
        AveragerSpec::ExpK { k: 10 },
        AveragerSpec::Gea { c: 0.5 },
        AveragerSpec::Awa {
            window: WindowKind::Fixed { k: 7 },
            accumulators: 2,
        },
        AveragerSpec::Awa {
            window: WindowKind::Growing { c: 0.4 },
            accumulators: 3,
        },
        AveragerSpec::True {
            window: WindowKind::Fixed { k: 9 },
        },
        AveragerSpec::True {
            window: WindowKind::Growing { c: 0.5 },
        },
        AveragerSpec::Raw {
            c: 0.5,
            total_steps: 200,
        },
        AveragerSpec::Restart {
            window: WindowKind::Fixed { k: 6 },
        },
        AveragerSpec::Eh {
            window: WindowKind::Fixed { k: 50 },
            eps: 0.1,
        },
        AveragerSpec::TwoTail { r: 0.5 },
    ]
}

/// Deterministic sample value for stream `s`, step `t`, dimension `i`.
fn sample(s: usize, t: u64, i: usize) -> f64 {
    (((t as f64) * 0.37 + (s as f64) * 1.7 + (i as f64) * 0.41).sin()) * 3.0
}

fn flat_batch(s: usize, start_t: u64, count: usize, d: usize) -> Vec<f64> {
    let mut out = Vec::with_capacity(count * d);
    for k in 0..count {
        for i in 0..d {
            out.push(sample(s, start_t + k as u64, i));
        }
    }
    out
}

fn close(a: &[f64], b: &[f64], ctx: &str) {
    assert_eq!(a.len(), b.len(), "{ctx}: length");
    for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
        assert!(
            (x - y).abs() <= 1e-12 * y.abs().max(1.0),
            "{ctx}[{i}]: {x} vs {y}"
        );
    }
}

// ---------------------------------------------------------------------------
// Estimator-level snapshot/restore properties
// ---------------------------------------------------------------------------

#[test]
fn snapshot_restore_midstream_equals_uninterrupted_every_spec() {
    Runner::new("snapshot/restore midstream equivalence", 0xD00D).run(20, |g| {
        let d = g.usize_range(1, 4);
        let n1 = g.usize_range(1, 60);
        let n2 = g.usize_range(1, 60);
        for spec in all_specs() {
            let label = spec.label();
            let mut reference = spec.build(d).unwrap();
            let mut first = spec.build(d).unwrap();
            let data1: Vec<f64> = (0..n1 * d).map(|_| g.f64_range(-5.0, 5.0)).collect();
            let data2: Vec<f64> = (0..n2 * d).map(|_| g.f64_range(-5.0, 5.0)).collect();
            reference.observe_many(&data1, n1);
            first.observe_many(&data1, n1);
            let mut enc = Enc::new();
            first.export_state(&mut enc);
            let bytes = enc.into_bytes();
            // Restore into a fresh estimator…
            let mut restored = spec.build(d).unwrap();
            restored
                .import_state(&mut Dec::new(&bytes))
                .map_err(|e| format!("{label}: import: {e}"))?;
            // …whose re-export is bitwise identical (two encode cycles).
            let mut enc2 = Enc::new();
            restored.export_state(&mut enc2);
            if enc2.as_bytes() != &bytes[..] {
                return Err(format!("{label}: re-export differs from original export"));
            }
            // Continuing the restored stream matches the uninterrupted one.
            reference.observe_many(&data2, n2);
            restored.observe_many(&data2, n2);
            if restored.t() != reference.t() {
                return Err(format!("{label}: t {} vs {}", restored.t(), reference.t()));
            }
            match (restored.value(), reference.value()) {
                (Some(a), Some(b)) => {
                    for i in 0..d {
                        if (a[i] - b[i]).abs() > 1e-12 * b[i].abs().max(1.0) {
                            return Err(format!("{label} dim {i}: {} vs {}", a[i], b[i]));
                        }
                    }
                }
                (None, None) => {}
                (a, b) => return Err(format!("{label}: presence {a:?} vs {b:?}")),
            }
        }
        Ok(())
    });
}

#[test]
fn import_rejects_cross_spec_and_wrong_dim_payloads() {
    let d = 2;
    for spec in all_specs() {
        let mut src = spec.build(d).unwrap();
        src.observe_many(&flat_batch(0, 0, 8, d), 8);
        let mut enc = Enc::new();
        src.export_state(&mut enc);
        let bytes = enc.into_bytes();
        // Wrong dim: same spec, different dimensionality.
        let mut other_dim = spec.build(d + 1).unwrap();
        assert!(
            other_dim.import_state(&mut Dec::new(&bytes)).is_err(),
            "{}: wrong dim must be rejected",
            spec.label()
        );
        // Wrong spec kind or parameters.
        for other in all_specs() {
            if other == spec {
                continue;
            }
            let mut tgt = other.build(d).unwrap();
            assert!(
                tgt.import_state(&mut Dec::new(&bytes)).is_err(),
                "{} payload must not import into {}",
                spec.label(),
                other.label()
            );
        }
    }
}

// ---------------------------------------------------------------------------
// Merge semantics
// ---------------------------------------------------------------------------

fn export_bytes(a: &dyn Averager) -> Vec<u8> {
    let mut enc = Enc::new();
    a.export_state(&mut enc);
    enc.into_bytes()
}

#[test]
fn gea_merge_is_exact_inverse_variance_pooling() {
    let spec = AveragerSpec::Gea { c: 0.5 };
    let mut a = spec.build(1).unwrap();
    let mut b = spec.build(1).unwrap();
    for t in 0..40u64 {
        a.observe_scalar(sample(0, t, 0));
    }
    for t in 0..90u64 {
        b.observe_scalar(sample(1, t, 0));
    }
    let (va, vb) = (a.value_scalar().unwrap(), b.value_scalar().unwrap());
    let bytes = export_bytes(&*b);
    a.merge_state(&mut Dec::new(&bytes)).unwrap();
    assert_eq!(a.t(), 40 + 90);
    // Inverse-variance weights: v tracks Σα² = 1/k_eff, so the combine
    // weights are the effective window sizes.
    let (ka, kb) = (0.5 * 40.0, 0.5 * 90.0); // k_eff = c·t after warmup
    let want = (ka * va + kb * vb) / (ka + kb);
    let got = a.value_scalar().unwrap();
    assert!((got - want).abs() < 1e-9, "{got} vs {want}");
}

#[test]
fn awa_merge_pools_accumulators_exactly() {
    for accumulators in [2u32, 3] {
        let spec = AveragerSpec::Awa {
            window: WindowKind::Fixed { k: 1000 }, // no flush: pure running means
            accumulators,
        };
        let mut a = spec.build(1).unwrap();
        let mut b = spec.build(1).unwrap();
        let (na, nb) = (12u64, 30u64);
        let mut sum = 0.0;
        for t in 0..na {
            let x = sample(0, t, 0);
            sum += x;
            a.observe_scalar(x);
        }
        for t in 0..nb {
            let x = sample(1, t, 0);
            sum += x;
            b.observe_scalar(x);
        }
        let bytes = export_bytes(&*b);
        a.merge_state(&mut Dec::new(&bytes)).unwrap();
        assert_eq!(a.t(), na + nb);
        // Below the window everything sits in the recent accumulators:
        // the merged estimate is the exact pooled mean of all samples.
        let want = sum / (na + nb) as f64;
        let got = a.value_scalar().unwrap();
        assert!(
            (got - want).abs() < 1e-12,
            "accumulators={accumulators}: {got} vs {want}"
        );
    }
}

#[test]
fn exp_merge_mass_weighted_combine() {
    let spec = AveragerSpec::Exp { gamma: 0.8 };
    // Two constant streams at the same level merge to that level…
    let mut a = spec.build(1).unwrap();
    let mut b = spec.build(1).unwrap();
    for _ in 0..30 {
        a.observe_scalar(5.0);
        b.observe_scalar(5.0);
    }
    let bytes = export_bytes(&*b);
    a.merge_state(&mut Dec::new(&bytes)).unwrap();
    assert_eq!(a.t(), 60);
    assert!((a.value_scalar().unwrap() - 5.0).abs() < 1e-12);
    // …and differing levels land at the mass-weighted midpoint.
    let mut c = spec.build(1).unwrap();
    let mut d = spec.build(1).unwrap();
    for _ in 0..200 {
        c.observe_scalar(2.0); // mass ≈ 1 each at t=200
        d.observe_scalar(4.0);
    }
    let bytes = export_bytes(&*d);
    c.merge_state(&mut Dec::new(&bytes)).unwrap();
    assert!((c.value_scalar().unwrap() - 3.0).abs() < 1e-9);
}

#[test]
fn windowed_merges_take_precedence_of_longer_stream() {
    for spec in [
        AveragerSpec::True {
            window: WindowKind::Fixed { k: 5 },
        },
        AveragerSpec::Restart {
            window: WindowKind::Fixed { k: 5 },
        },
        AveragerSpec::Eh {
            window: WindowKind::Fixed { k: 20 },
            eps: 0.1,
        },
    ] {
        let mut short = spec.build(1).unwrap();
        let mut long = spec.build(1).unwrap();
        for t in 0..8u64 {
            short.observe_scalar(sample(0, t, 0));
        }
        for t in 0..40u64 {
            long.observe_scalar(sample(1, t, 0));
        }
        let long_val = long.value_scalar().unwrap();
        let long_t = long.t();
        // Longer peer wins outright…
        let bytes = export_bytes(&*long);
        short.merge_state(&mut Dec::new(&bytes)).unwrap();
        assert_eq!(short.t(), long_t, "{}", spec.label());
        assert_eq!(short.value_scalar().unwrap(), long_val, "{}", spec.label());
        // …and a shorter peer leaves the state untouched.
        let mut tiny = spec.build(1).unwrap();
        tiny.observe_scalar(9.0);
        let tiny_bytes = export_bytes(&*tiny);
        short.merge_state(&mut Dec::new(&tiny_bytes)).unwrap();
        assert_eq!(short.t(), long_t, "{}", spec.label());
        assert_eq!(short.value_scalar().unwrap(), long_val, "{}", spec.label());
    }
}

// ---------------------------------------------------------------------------
// Coordinator-level state transfer (slot AND banked backings)
// ---------------------------------------------------------------------------

#[test]
fn coordinator_export_restore_roundtrips_across_coordinators() {
    let d = 3;
    let a = Coordinator::new(2, 256, BackpressurePolicy::Block);
    let b = Coordinator::new(3, 256, BackpressurePolicy::Block); // different sharding
    let reference = Coordinator::new(1, 256, BackpressurePolicy::Block);
    for (s, spec) in all_specs().into_iter().enumerate() {
        let name = format!("s{s}");
        for c in [&a, &b, &reference] {
            c.register(&name, d, spec.clone()).unwrap();
        }
        // First half into A (and the uninterrupted reference).
        let h1 = flat_batch(s, 0, 20, d);
        a.push_many(&name, 20, &h1).unwrap();
        reference.push_many(&name, 20, &h1).unwrap();
    }
    a.sync().unwrap();
    for (s, spec) in all_specs().into_iter().enumerate() {
        let name = format!("s{s}");
        // Move the stream's state A → B over the framed payload.
        let framed = a.export_state(&name).unwrap();
        let t = b.restore_state(&name, &framed).unwrap();
        assert_eq!(t, 20, "{}", spec.label());
        // Continue on B; the reference runs uninterrupted.
        let h2 = flat_batch(s, 20, 15, d);
        b.push_many(&name, 15, &h2).unwrap();
        reference.push_many(&name, 15, &h2).unwrap();
    }
    b.sync().unwrap();
    reference.sync().unwrap();
    for (s, spec) in all_specs().into_iter().enumerate() {
        let name = format!("s{s}");
        let got = b.snapshot(&name).unwrap();
        let want = reference.snapshot(&name).unwrap();
        assert_eq!(got.t, want.t, "{}", spec.label());
        close(
            &got.value.expect("value"),
            &want.value.expect("value"),
            &spec.label(),
        );
    }
    // Malformed framed payloads are structured errors, never panics.
    assert!(b.restore_state("s0", b"not a framed payload").is_err());
    let mut framed = a.export_state("s0").unwrap();
    let last = framed.len() - 1;
    framed[last] ^= 0x01;
    assert!(b.restore_state("s0", &framed).is_err());
}

#[test]
fn coordinator_merge_rolls_up_shard_partials() {
    // Two "shards" each averaged a disjoint half of a GEA stream; the
    // rollup merge pools them exactly (banked backing on both sides).
    let d = 2;
    let spec = AveragerSpec::Gea { c: 0.5 };
    let a = Coordinator::new(2, 256, BackpressurePolicy::Block);
    let b = Coordinator::new(2, 256, BackpressurePolicy::Block);
    for c in [&a, &b] {
        c.register("w", d, spec.clone()).unwrap();
    }
    a.push_many("w", 30, &flat_batch(0, 0, 30, d)).unwrap();
    b.push_many("w", 50, &flat_batch(1, 0, 50, d)).unwrap();
    a.sync().unwrap();
    b.sync().unwrap();
    let partial = b.export_state("w").unwrap();
    let t = a.merge_state("w", &partial).unwrap();
    assert_eq!(t, 80);
    let merged = a.snapshot("w").unwrap();
    assert_eq!(merged.t, 80);
    assert!(merged.value.is_some());
    // Slot-backed merge too (True window → precedence).
    let spec = AveragerSpec::True {
        window: WindowKind::Fixed { k: 4 },
    };
    for c in [&a, &b] {
        c.register("tw", 1, spec.clone()).unwrap();
    }
    a.push_many("tw", 3, &flat_batch(2, 0, 3, 1)).unwrap();
    b.push_many("tw", 9, &flat_batch(3, 0, 9, 1)).unwrap();
    a.sync().unwrap();
    b.sync().unwrap();
    let longer = b.export_state("tw").unwrap();
    assert_eq!(a.merge_state("tw", &longer).unwrap(), 9);
    let got = a.snapshot("tw").unwrap();
    let want = b.snapshot("tw").unwrap();
    assert_eq!(got.t, want.t);
    assert_eq!(got.value.unwrap(), want.value.unwrap());
}

// ---------------------------------------------------------------------------
// Checkpoint + WAL crash recovery
// ---------------------------------------------------------------------------

fn persist_cfg(dir: &Path, shards: usize) -> ServiceConfig {
    ServiceConfig {
        shards,
        queue_capacity: 256,
        persist: Some(PersistConfig {
            dir: dir.display().to_string(),
            segment_bytes: 16 << 10,
            fsync: false,
            checkpoint_interval_ms: 0,
            group_commit_micros: 0,
        }),
        ..Default::default()
    }
}

#[test]
fn kill_and_recover_restores_every_spec_exactly() {
    let dir = temp_dir("persist-kill-recover");
    let cfg = persist_cfg(&dir, 2);
    let reference = Coordinator::new(2, 256, BackpressurePolicy::Block);
    {
        let durable = Coordinator::from_config(&cfg).unwrap();
        let d = 3;
        for (s, spec) in all_specs().into_iter().enumerate() {
            let name = format!("s{s}");
            durable.register(&name, d, spec.clone()).unwrap();
            reference.register(&name, d, spec).unwrap();
            let h1 = flat_batch(s, 0, 17, d);
            durable.push_many(&name, 17, &h1).unwrap();
            reference.push_many(&name, 17, &h1).unwrap();
        }
        durable.sync().unwrap();
        // Checkpoint mid-stream, then keep pushing so the WAL has a
        // live tail past the snapshot.
        let report = durable.checkpoint().unwrap();
        assert_eq!(report.streams, all_specs().len());
        for s in 0..all_specs().len() {
            let name = format!("s{s}");
            let h2 = flat_batch(s, 17, 23, 3);
            durable.push_many(&name, 23, &h2).unwrap();
            reference.push_many(&name, 23, &h2).unwrap();
        }
        durable.sync().unwrap();
        // "Crash": drop without another checkpoint.
    }
    let (recovered, report) = Coordinator::recover(&cfg).unwrap();
    assert!(report.snapshot.is_some());
    assert_eq!(report.restored_streams, all_specs().len());
    assert!(report.replayed_batches > 0);
    reference.sync().unwrap();
    for (s, spec) in all_specs().into_iter().enumerate() {
        let name = format!("s{s}");
        let got = recovered.snapshot(&name).unwrap();
        let want = reference.snapshot(&name).unwrap();
        assert_eq!(got.t, want.t, "{}", spec.label());
        close(
            &got.value.expect("value"),
            &want.value.expect("value"),
            &format!("recovered {}", spec.label()),
        );
    }
    // The recovered coordinator keeps working and stays equivalent.
    for s in 0..all_specs().len() {
        let name = format!("s{s}");
        let h3 = flat_batch(s, 40, 11, 3);
        recovered.push_many(&name, 11, &h3).unwrap();
        reference.push_many(&name, 11, &h3).unwrap();
    }
    recovered.sync().unwrap();
    reference.sync().unwrap();
    for (s, spec) in all_specs().into_iter().enumerate() {
        let name = format!("s{s}");
        let got = recovered.snapshot(&name).unwrap();
        let want = reference.snapshot(&name).unwrap();
        assert_eq!(got.t, want.t);
        close(
            &got.value.expect("value"),
            &want.value.expect("value"),
            &format!("post-recovery {}", spec.label()),
        );
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// A durable config with `fsync = true` and a wide group-commit window
/// (everything rides on forced commits at sync barriers).
fn group_commit_cfg(dir: &Path, micros: u64) -> ServiceConfig {
    ServiceConfig {
        shards: 1,
        queue_capacity: 256,
        persist: Some(PersistConfig {
            dir: dir.display().to_string(),
            segment_bytes: 1 << 20,
            fsync: true,
            checkpoint_interval_ms: 0,
            group_commit_micros: micros,
        }),
        ..Default::default()
    }
}

/// Concatenated bytes of every WAL segment under `dir/wal/shard-0`.
fn shard0_wal_segments(dir: &Path) -> Vec<u8> {
    let shard = dir.join("wal").join("shard-0");
    let mut out = Vec::new();
    for seg in wal::list_segments(&shard) {
        let path = shard.join(format!("seg-{seg:08}.wal"));
        out.extend_from_slice(&std::fs::read(path).unwrap());
    }
    out
}

#[test]
fn group_commit_crash_recovers_every_acked_batch() {
    // Fault injection: acked (sync-barrier) batches ride a forced group
    // commit, so they must survive even when the crash tears off the
    // un-acked WAL tail written after the last barrier.
    let dir = temp_dir("persist-group-kill");
    let cfg = group_commit_cfg(&dir, 100_000);
    let d = 2;
    let acked_batches = 10usize;
    let per_batch = 4usize;
    {
        let durable = Coordinator::from_config(&cfg).unwrap();
        durable.register("g", d, AveragerSpec::Gea { c: 0.5 }).unwrap();
        for b in 0..acked_batches {
            let data = flat_batch(0, (b * per_batch) as u64, per_batch, d);
            durable.push_many("g", per_batch, &data).unwrap();
            durable.sync().unwrap(); // ack: forces the group's fsync
        }
        // A tail of extra batches the simulated crash below will tear
        // into (the first ten barriers' batches must stay untouched).
        for b in acked_batches..acked_batches + 6 {
            let data = flat_batch(0, (b * per_batch) as u64, per_batch, d);
            durable.push_many("g", per_batch, &data).unwrap();
        }
        durable.sync().unwrap();
    }
    // Simulate the kill mid-group: chop bytes off the WAL tail (the
    // un-synced page-cache writes a real crash would lose). 100 bytes
    // is within the post-barrier records — the acked prefix is intact.
    let shard = dir.join("wal").join("shard-0");
    let last = *wal::list_segments(&shard).last().unwrap();
    let seg_path = shard.join(format!("seg-{last:08}.wal"));
    let pristine = std::fs::read(&seg_path).unwrap();
    std::fs::write(&seg_path, &pristine[..pristine.len() - 100]).unwrap();
    let (recovered, report) = Coordinator::recover(&cfg).unwrap();
    assert!(!report.wal_clean, "the torn tail must be detected");
    let snap = recovered.snapshot("g").unwrap();
    let survived = snap.t as usize;
    assert!(
        survived >= acked_batches * per_batch,
        "acked batches lost: {survived} < {}",
        acked_batches * per_batch
    );
    // Whatever prefix survived must match an uninterrupted reference
    // fed exactly those samples.
    let mut reference = AveragerSpec::Gea { c: 0.5 }.build(d).unwrap();
    reference.observe_many(&flat_batch(0, 0, survived, d), survived);
    close(
        &snap.value.expect("value"),
        &reference.value().expect("value"),
        "group-commit crash prefix",
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn group_commit_wal_bytes_match_per_append_mode() {
    // Grouping re-times fsyncs; it must never re-frame. The same push
    // sequence through a grouped coordinator and a per-append-fsync one
    // must produce byte-identical WAL segments.
    let dir_grp = temp_dir("persist-group-bytes");
    let dir_per = temp_dir("persist-perappend-bytes");
    let cfg_grp = group_commit_cfg(&dir_grp, 100_000);
    let cfg_per = group_commit_cfg(&dir_per, 0);
    {
        let grp = Coordinator::from_config(&cfg_grp).unwrap();
        let per = Coordinator::from_config(&cfg_per).unwrap();
        for c in [&grp, &per] {
            c.register("a", 2, AveragerSpec::Gea { c: 0.5 }).unwrap();
            c.register("b", 1, AveragerSpec::ExpK { k: 8 }).unwrap();
        }
        for b in 0..12 {
            let batch_a = flat_batch(0, b * 3, 3, 2);
            let batch_b = flat_batch(1, b * 2, 2, 1);
            for c in [&grp, &per] {
                c.push_many("a", 3, &batch_a).unwrap();
                c.push_many("b", 2, &batch_b).unwrap();
                if b % 4 == 3 {
                    c.sync().unwrap();
                }
            }
        }
        for c in [&grp, &per] {
            c.sync().unwrap();
        }
    }
    let a = shard0_wal_segments(&dir_grp);
    let b = shard0_wal_segments(&dir_per);
    assert!(!a.is_empty());
    assert_eq!(a, b, "group commit changed WAL bytes");
    let _ = std::fs::remove_dir_all(&dir_grp);
    let _ = std::fs::remove_dir_all(&dir_per);
}

#[test]
fn recovery_without_any_checkpoint_replays_the_full_wal() {
    // Crash before the FIRST checkpoint: no snapshot exists, and the
    // replay fallback position {segment 0, offset 0} must still skip
    // the segment header and recover every acknowledged record
    // (regression: offset 0 used to parse the magic as a torn frame).
    let dir = temp_dir("persist-no-checkpoint");
    let cfg = persist_cfg(&dir, 2);
    {
        let durable = Coordinator::from_config(&cfg).unwrap();
        durable
            .register("banked", 2, AveragerSpec::Gea { c: 0.5 })
            .unwrap();
        durable
            .register(
                "slotted",
                1,
                AveragerSpec::True {
                    window: WindowKind::Fixed { k: 4 },
                },
            )
            .unwrap();
        durable
            .push_many("banked", 12, &flat_batch(0, 0, 12, 2))
            .unwrap();
        durable
            .push_many("slotted", 7, &flat_batch(1, 0, 7, 1))
            .unwrap();
        durable.sync().unwrap();
        // Crash: no checkpoint was ever written.
    }
    let (recovered, report) = Coordinator::recover(&cfg).unwrap();
    assert!(report.snapshot.is_none());
    assert_eq!(report.replayed_registers, 2, "{report:?}");
    assert_eq!(report.replayed_batches, 2, "{report:?}");
    assert_eq!(recovered.snapshot("banked").unwrap().t, 12);
    assert_eq!(recovered.snapshot("slotted").unwrap().t, 7);
    // Values match uninterrupted references.
    let mut reference = AveragerSpec::Gea { c: 0.5 }.build(2).unwrap();
    reference.observe_many(&flat_batch(0, 0, 12, 2), 12);
    close(
        &recovered.snapshot("banked").unwrap().value.expect("value"),
        &reference.value().expect("value"),
        "no-checkpoint banked",
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn streams_registered_after_checkpoint_survive_via_wal() {
    let dir = temp_dir("persist-late-register");
    let cfg = persist_cfg(&dir, 2);
    {
        let durable = Coordinator::from_config(&cfg).unwrap();
        durable
            .register("early", 1, AveragerSpec::Gea { c: 0.5 })
            .unwrap();
        durable.push_many("early", 5, &flat_batch(0, 0, 5, 1)).unwrap();
        durable.sync().unwrap();
        durable.checkpoint().unwrap();
        // Born after the checkpoint: only the WAL knows about these.
        durable
            .register("late-banked", 1, AveragerSpec::Exp { gamma: 0.5 })
            .unwrap();
        durable
            .register(
                "late-slot",
                1,
                AveragerSpec::True {
                    window: WindowKind::Fixed { k: 3 },
                },
            )
            .unwrap();
        durable
            .push_many("late-banked", 4, &flat_batch(1, 0, 4, 1))
            .unwrap();
        durable
            .push_many("late-slot", 6, &flat_batch(2, 0, 6, 1))
            .unwrap();
        // And one unregistered after the checkpoint must stay gone.
        durable
            .register("doomed", 1, AveragerSpec::Gea { c: 0.5 })
            .unwrap();
        durable.sync().unwrap();
        durable.unregister("doomed").unwrap();
        durable.sync().unwrap();
    }
    let (recovered, report) = Coordinator::recover(&cfg).unwrap();
    assert!(report.replayed_registers >= 2, "{report:?}");
    assert_eq!(recovered.snapshot("early").unwrap().t, 5);
    assert_eq!(recovered.snapshot("late-banked").unwrap().t, 4);
    assert_eq!(recovered.snapshot("late-slot").unwrap().t, 6);
    assert!(recovered.snapshot("doomed").is_err());
    let _ = std::fs::remove_dir_all(&dir);
}

// ---------------------------------------------------------------------------
// Deterministic kill-and-query soak: analytics stats are bitwise-stable
// across checkpoint/crash/recover
// ---------------------------------------------------------------------------

/// Streams, banked AND slot-backed, the soak interleaves over.
fn soak_specs() -> Vec<(&'static str, AveragerSpec)> {
    vec![
        ("b/gea", AveragerSpec::Gea { c: 0.5 }),
        ("b/exp", AveragerSpec::ExpK { k: 10 }),
        (
            "b/awa",
            AveragerSpec::Awa {
                window: WindowKind::Growing { c: 0.4 },
                accumulators: 3,
            },
        ),
        (
            "s/true",
            AveragerSpec::True {
                window: WindowKind::Fixed { k: 9 },
            },
        ),
        (
            "s/eh",
            AveragerSpec::Eh {
                window: WindowKind::Fixed { k: 30 },
                eps: 0.1,
            },
        ),
    ]
}

/// Every stream's StatSnapshot on `got` must be BITWISE identical to
/// `want`'s — mean, variance, ESS and effective window compared by
/// to_bits, not tolerance. This is what makes the confidence bands
/// trustworthy across crashes: recovery replays the same whole-batch
/// boundaries through the same kernels, and state imports are
/// byte-exact (TrueWindow ships its live running sums for exactly this
/// reason).
fn assert_stats_bitwise(
    got: &Coordinator,
    want: &Coordinator,
    specs: &[(&'static str, AveragerSpec)],
    round: u64,
) {
    for (name, spec) in specs {
        let a = got.stat_snapshot(name).unwrap();
        let b = want.stat_snapshot(name).unwrap();
        let ctx = format!("round {round} stream {name} ({})", spec.label());
        assert_eq!(a.t, b.t, "{ctx}: t");
        assert_eq!(a.ess.to_bits(), b.ess.to_bits(), "{ctx}: ess {} vs {}", a.ess, b.ess);
        assert_eq!(
            a.effective_window.to_bits(),
            b.effective_window.to_bits(),
            "{ctx}: k_eff"
        );
        for i in 0..a.mean.len() {
            assert_eq!(
                a.mean[i].to_bits(),
                b.mean[i].to_bits(),
                "{ctx}: mean[{i}] {} vs {}",
                a.mean[i],
                b.mean[i]
            );
            assert_eq!(
                a.variance[i].to_bits(),
                b.variance[i].to_bits(),
                "{ctx}: variance[{i}] {} vs {}",
                a.variance[i],
                b.variance[i]
            );
        }
    }
}

#[test]
fn kill_and_query_soak_stat_snapshots_bitwise_stable() {
    use ata::analytics::Query;
    use ata::rng::{RngCore, Xoshiro256};
    let dir = temp_dir("persist-query-soak");
    let cfg = persist_cfg(&dir, 2);
    let d = 2usize;
    let specs = soak_specs();
    let reference = Coordinator::new(2, 256, BackpressurePolicy::Block);
    let mut durable = Coordinator::from_config(&cfg).unwrap();
    for (name, spec) in &specs {
        durable.register(name, d, spec.clone()).unwrap();
        reference.register(name, d, spec.clone()).unwrap();
    }
    // Seeded schedule: which stream, how many samples, and when to
    // sync/query/checkpoint/crash — fully reproducible.
    let mut rng = Xoshiro256::seed_from_u64(0x50AC);
    let mut pos = vec![0u64; specs.len()];
    for round in 0..120u64 {
        let s = rng.next_below(specs.len() as u64) as usize;
        let count = 1 + rng.next_below(7) as usize;
        let batch = flat_batch(s, pos[s], count, d);
        pos[s] += count as u64;
        durable.push_many(specs[s].0, count, &batch).unwrap();
        reference.push_many(specs[s].0, count, &batch).unwrap();
        if round % 5 == 4 {
            durable.sync().unwrap();
            reference.sync().unwrap();
            assert_stats_bitwise(&durable, &reference, &specs, round);
        }
        if round % 13 == 12 {
            durable.checkpoint().unwrap();
        }
        if round % 40 == 39 {
            // "Kill": drop without a final checkpoint; recover from the
            // snapshot + WAL tail and re-check every stream bitwise.
            drop(durable);
            let (recovered, _report) = Coordinator::recover(&cfg).unwrap();
            durable = recovered;
            reference.sync().unwrap();
            assert_stats_bitwise(&durable, &reference, &specs, round);
        }
    }
    // The query layer sees identical numbers too (aggregate pools in
    // name order on both sides).
    durable.sync().unwrap();
    reference.sync().unwrap();
    let qa = durable.query(&Query {
        prefix: "b/".into(),
        aggregate: true,
        ..Query::default()
    });
    let qb = reference.query(&Query {
        prefix: "b/".into(),
        aggregate: true,
        ..Query::default()
    });
    assert_eq!(qa.aggregated, 3);
    assert_eq!(qa.aggregated, qb.aggregated);
    let (a, b) = (qa.aggregate.unwrap(), qb.aggregate.unwrap());
    assert_eq!(a.ess.to_bits(), b.ess.to_bits());
    for i in 0..d {
        assert_eq!(a.mean[i].to_bits(), b.mean[i].to_bits());
        assert_eq!(a.variance[i].to_bits(), b.variance[i].to_bits());
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// Recursive dir copy (std-only) for fault-injection snapshots.
fn copy_dir(src: &Path, dst: &Path) {
    std::fs::create_dir_all(dst).unwrap();
    for entry in std::fs::read_dir(src).unwrap().flatten() {
        let ty = entry.file_type().unwrap();
        let to = dst.join(entry.file_name());
        if ty.is_dir() {
            copy_dir(&entry.path(), &to);
        } else {
            std::fs::copy(entry.path(), &to).unwrap();
        }
    }
}

#[test]
fn wal_truncation_never_panics_and_never_loses_surviving_batches() {
    // Build a pristine durable state: a checkpoint plus a WAL tail of
    // known per-stream batches, all on ONE shard so the truncation
    // point maps to a deterministic batch prefix.
    let dir = temp_dir("persist-truncate");
    let cfg = persist_cfg(&dir, 1);
    let d = 2;
    let specs = [
        ("g", AveragerSpec::Gea { c: 0.5 }),
        (
            "t",
            AveragerSpec::True {
                window: WindowKind::Fixed { k: 5 },
            },
        ),
    ];
    // Per-stream batch schedule after the checkpoint: (stream, count).
    let schedule: Vec<(usize, usize)> =
        vec![(0, 3), (1, 2), (0, 5), (1, 7), (0, 1), (1, 4), (0, 6)];
    {
        let durable = Coordinator::from_config(&cfg).unwrap();
        for (name, spec) in &specs {
            durable.register(name, d, spec.clone()).unwrap();
        }
        durable.push_many("g", 10, &flat_batch(0, 0, 10, d)).unwrap();
        durable.push_many("t", 10, &flat_batch(1, 0, 10, d)).unwrap();
        durable.sync().unwrap();
        durable.checkpoint().unwrap();
        let mut pos = [10u64, 10u64];
        for &(s, count) in &schedule {
            let name = specs[s].0;
            durable
                .push_many(name, count, &flat_batch(s, pos[s], count, d))
                .unwrap();
            pos[s] += count as u64;
        }
        durable.sync().unwrap();
    }
    let pristine = temp_dir("persist-truncate-pristine");
    copy_dir(&dir, &pristine);
    // The post-checkpoint records live in the highest segment(s) of the
    // single shard's WAL.
    let shard_dir = dir.join("wal").join("shard-0");
    let last_seg = *wal::list_segments(&shard_dir).last().unwrap();
    let seg_path = shard_dir.join(format!("seg-{last_seg:08}.wal"));
    let seg_bytes = std::fs::read(&seg_path).unwrap();
    // Truncate the tail segment at a spread of arbitrary byte offsets.
    let cuts: Vec<usize> = (0..=12).map(|i| i * seg_bytes.len() / 12).collect();
    for cut in cuts {
        let _ = std::fs::remove_dir_all(&dir);
        copy_dir(&pristine, &dir);
        std::fs::write(&seg_path, &seg_bytes[..cut.min(seg_bytes.len())]).unwrap();
        let (recovered, _report) = Coordinator::recover(&cfg).unwrap();
        // Work out, per stream, how many whole batches survived, from
        // the recovered t — then the state must match a reference fed
        // exactly that batch prefix (same batch boundaries).
        for (s, (name, spec)) in specs.iter().enumerate() {
            let snap = recovered.snapshot(name).unwrap();
            assert!(snap.t >= 10, "checkpointed state lost at cut {cut}");
            let mut reference = spec.build(d).unwrap();
            reference.observe_many(&flat_batch(s, 0, 10, d), 10);
            let mut pos = 10u64;
            for &(bs, count) in &schedule {
                if bs != s || pos >= snap.t {
                    continue;
                }
                reference.observe_many(&flat_batch(s, pos, count, d), count);
                pos += count as u64;
            }
            assert_eq!(
                snap.t, pos,
                "cut {cut}: stream {name} t={} is not a whole-batch prefix",
                snap.t
            );
            close(
                &snap.value.expect("value"),
                &reference.value().expect("value"),
                &format!("cut {cut} stream {name}"),
            );
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
    let _ = std::fs::remove_dir_all(&pristine);
}

// ---------------------------------------------------------------------------
// Graceful drain: the shutdown path must close the group-commit window
// ---------------------------------------------------------------------------

/// `Server::drain` promises that every acked push is on disk when it
/// returns: it runs the sync barrier, and the barrier forces an open
/// WAL group commit. With a near-1s commit window and no explicit
/// client sync, the drain is the *only* thing standing between these
/// acks and the recovery losing them — the recovered estimates must
/// come back bit for bit.
#[test]
fn drain_closes_the_group_commit_window_and_recovers_bitwise() {
    use ata::coordinator::{Client, Server, ServerOptions};
    use std::sync::Arc;
    use std::time::Duration;

    let dir = temp_dir("persist-drain-commit");
    let mut cfg = persist_cfg(&dir, 2);
    if let Some(p) = cfg.persist.as_mut() {
        p.fsync = true;
        p.group_commit_micros = 900_000;
    }
    let coordinator = Arc::new(Coordinator::from_config(&cfg).expect("durable coordinator"));
    let mut server = Server::start_with_options(
        "127.0.0.1:0",
        Arc::clone(&coordinator),
        2,
        ServerOptions::default(),
    )
    .expect("server");
    {
        let mut cl = Client::connect(&server.addr().to_string()).expect("client");
        cl.register("drained", 2, "gea(c=0.5)").expect("register");
        for b in 0..10u64 {
            cl.push_many("drained", 4, &flat_batch(0, b * 4, 4, 2))
                .expect("push");
        }
        // Deliberately NO client sync: the acks sit inside the open
        // group-commit window when the drain begins.
    }
    server.drain(Duration::from_secs(5));
    let live = coordinator.snapshot("drained").expect("live snapshot");
    assert_eq!(live.t, 40);
    let live_bits: Vec<u64> = live
        .value
        .as_deref()
        .expect("estimate")
        .iter()
        .map(|x| x.to_bits())
        .collect();
    drop(server);
    drop(coordinator);

    let (recovered, report) = Coordinator::recover(&cfg).expect("recover");
    assert!(report.wal_clean, "clean shutdown must leave a clean WAL");
    assert_eq!(report.replayed_samples, 40, "{report:?}");
    let got = recovered.snapshot("drained").expect("recovered snapshot");
    assert_eq!(got.t, live.t);
    let got_bits: Vec<u64> = got
        .value
        .as_deref()
        .expect("estimate")
        .iter()
        .map(|x| x.to_bits())
        .collect();
    assert_eq!(got_bits, live_bits, "recovery must be bitwise-identical");
    let _ = std::fs::remove_dir_all(&dir);
}
