//! Integration: the AOT artifacts load, compile and agree with the
//! native Rust implementations on identical inputs.
//!
//! Skips (with a notice) when `make artifacts` has not been run.

use ata::linreg::{LinRegProblem, Sgd, SgdConfig};
use ata::rng::{GaussianSource, Xoshiro256};
use ata::runtime::{artifacts_available, Runtime, DEFAULT_ARTIFACTS_DIR};

fn runtime_or_skip() -> Option<Runtime> {
    if !artifacts_available(DEFAULT_ARTIFACTS_DIR) {
        eprintln!("SKIP: no artifacts/ — run `make artifacts` first");
        return None;
    }
    match Runtime::from_dir(DEFAULT_ARTIFACTS_DIR) {
        Ok(rt) => Some(rt),
        // Artifacts exist but the binary was built without the `xla`
        // feature (stub runtime): skip rather than fail.
        Err(e) => {
            eprintln!("SKIP: runtime unavailable: {e}");
            None
        }
    }
}

fn f32s(v: &[f64]) -> Vec<f32> {
    v.iter().map(|&x| x as f32).collect()
}

#[test]
fn all_manifest_entries_compile_and_run_on_zeros() {
    let Some(rt) = runtime_or_skip() else { return };
    let names: Vec<String> = rt.manifest().entries.keys().cloned().collect();
    assert!(names.len() >= 5, "expected ≥5 entries, got {names:?}");
    for name in names {
        let entry = rt.load(&name).expect("load");
        let zeros: Vec<Vec<f32>> = entry
            .spec()
            .inputs
            .iter()
            .map(|t| vec![0.0f32; t.elements()])
            .collect();
        let refs: Vec<&[f32]> = zeros.iter().map(Vec::as_slice).collect();
        let out = entry.call(&refs).expect("call");
        assert_eq!(out.len(), entry.spec().outputs.len(), "{name}");
        for (o, spec) in out.iter().zip(&entry.spec().outputs) {
            assert_eq!(o.len(), spec.elements(), "{name}");
            assert!(o.iter().all(|v| v.is_finite()), "{name}: non-finite");
        }
    }
}

#[test]
fn sgd_step_matches_native_rust() {
    let Some(rt) = runtime_or_skip() else { return };
    let problem = LinRegProblem::paper_default();
    let cfg = SgdConfig::paper_default();
    let mut gauss = GaussianSource::new(Xoshiro256::seed_from_u64(777));
    let d = problem.d;
    let b = cfg.batch_size;

    // Native step on explicit data == PJRT step on the same data.
    let mut xs = vec![0.0f64; b * d];
    let mut ys = vec![0.0f64; b];
    problem.sample_batch(&mut gauss, &mut xs, &mut ys);
    let w0: Vec<f64> = (0..d).map(|i| (i as f64 * 0.1).sin()).collect();

    // Native: replicate Sgd::step arithmetic on the given batch.
    let mut resid = vec![0.0f64; b];
    for i in 0..b {
        let row = &xs[i * d..(i + 1) * d];
        resid[i] = row.iter().zip(&w0).map(|(x, w)| x * w).sum::<f64>() - ys[i];
    }
    let scale = cfg.step_size / b as f64;
    let mut w_native = w0.clone();
    for i in 0..b {
        let coeff = scale * resid[i];
        let row = &xs[i * d..(i + 1) * d];
        for (w, &x) in w_native.iter_mut().zip(row) {
            *w -= coeff * x;
        }
    }

    let out = rt
        .call(
            "sgd_step_d50_b11",
            &[
                &f32s(&w0),
                &f32s(&xs),
                &f32s(&ys),
                &[cfg.step_size as f32],
            ],
        )
        .expect("pjrt sgd_step");
    let w_pjrt = &out[0];
    for i in 0..d {
        let diff = (w_pjrt[i] as f64 - w_native[i]).abs();
        assert!(
            diff < 1e-4 * w_native[i].abs().max(1.0),
            "dim {i}: pjrt {} vs native {}",
            w_pjrt[i],
            w_native[i]
        );
    }
}

#[test]
fn sgd_chunk_equals_repeated_steps_and_tracks_native_trajectory() {
    let Some(rt) = runtime_or_skip() else { return };
    let problem = LinRegProblem::paper_default();
    let cfg = SgdConfig::paper_default();
    let d = problem.d;
    let b = cfg.batch_size;
    let s = 100usize; // must match the exported chunk length

    // Sample S batches with the SAME generator stream the native SGD
    // will consume, so trajectories are comparable.
    let seed = 4242u64;
    let mut gauss = GaussianSource::new(Xoshiro256::seed_from_u64(seed));
    let mut xs_all = vec![0.0f64; s * b * d];
    let mut ys_all = vec![0.0f64; s * b];
    for i in 0..s {
        let (xs, ys) = (
            &mut xs_all[i * b * d..(i + 1) * b * d],
            &mut ys_all[i * b..(i + 1) * b],
        );
        problem.sample_batch(&mut gauss, xs, ys);
    }

    // PJRT chunk from w0 = 0.
    let w0 = vec![0.0f32; d];
    let out = rt
        .call(
            "sgd_chunk_d50_b11_s100",
            &[
                &w0,
                &f32s(&xs_all),
                &f32s(&ys_all),
                &[cfg.step_size as f32],
            ],
        )
        .expect("pjrt chunk");
    let (w_final, iterates) = (&out[0], &out[1]);
    assert_eq!(iterates.len(), s * d);
    // Final iterate consistency within the artifact.
    for i in 0..d {
        assert_eq!(w_final[i], iterates[(s - 1) * d + i]);
    }

    // Native trajectory on the same data stream (same seed => same data).
    let mut native = Sgd::new(problem.clone(), cfg, seed).expect("sgd");
    let mut max_rel = 0.0f64;
    for step in 0..s {
        native.step();
        if step % 20 == 19 {
            let w_n = native.w();
            for i in 0..d {
                let p = iterates[step * d + i] as f64;
                let rel = (p - w_n[i]).abs() / w_n[i].abs().max(1.0);
                max_rel = max_rel.max(rel);
            }
        }
    }
    // f32 vs f64 accumulation over 100 steps: loose but meaningful bound.
    assert!(
        max_rel < 5e-3,
        "PJRT/native trajectory divergence: {max_rel}"
    );
    let final_excess_native = native.excess_error();
    let w_final_f64: Vec<f64> = w_final.iter().map(|&x| x as f64).collect();
    let final_excess_pjrt = native.problem().excess_error(&w_final_f64);
    assert!(
        (final_excess_native - final_excess_pjrt).abs()
            < 0.05 * final_excess_native.max(1e-6),
        "excess mismatch: native {final_excess_native} vs pjrt {final_excess_pjrt}"
    );
}

#[test]
fn lerp_combine_matches_rust_math() {
    let Some(rt) = runtime_or_skip() else { return };
    let d = 50;
    let a: Vec<f32> = (0..d).map(|i| (i as f32 * 0.3).sin()).collect();
    let b: Vec<f32> = (0..d).map(|i| (i as f32 * 0.9).cos()).collect();
    for gamma in [0.0f32, 0.25, 0.7, 1.0] {
        let out = rt
            .call("lerp_combine_d50", &[&a, &b, &[gamma]])
            .expect("lerp");
        for i in 0..d {
            let want = gamma * a[i] + (1.0 - gamma) * b[i];
            assert!((out[0][i] - want).abs() < 1e-6, "γ={gamma} i={i}");
        }
    }
}

#[test]
fn awa_snapshot_matches_rust_averager() {
    // Feed the same stream to the Rust AwaMulti and reconstruct the
    // estimate via the AOT awa_snapshot graph from the accumulator state.
    let Some(rt) = runtime_or_skip() else { return };
    use ata::averagers::{Averager, AwaMulti, WindowKind};
    let d = 50;
    let c = 0.5;
    let z = 3; // 4 accumulators total, matches awa_snapshot_m4_d50
    let mut awa = AwaMulti::new(d, WindowKind::Growing { c }, z);
    let mut gauss = GaussianSource::new(Xoshiro256::seed_from_u64(9));
    let mut x = vec![0.0f64; d];
    for _ in 0..300 {
        gauss.fill_standard(&mut x);
        awa.observe(&x);
    }
    let rust_value = awa.value().expect("value");

    // Rebuild means matrix from a parallel replay (the accumulator means
    // are internal; reconstruct by replaying into a fresh AwaMulti and
    // reading its public state via counts + a probing trick is overkill —
    // instead drive the snapshot graph with hand-built state and compare
    // against the same combine in Rust).
    let counts = awa.counts().to_vec();
    // Hand-built means: deterministic values; compute expected combine in
    // Rust with the same formula the averager uses.
    let m = z as usize + 1;
    let mut means = vec![0.0f32; m * d];
    for (i, mv) in means.iter_mut().enumerate() {
        *mv = ((i as f32) * 0.017).sin();
    }
    let counts_f: Vec<f32> = counts.iter().map(|&c| c as f32).collect();
    let k_t = (c * awa.t() as f64) as f32;
    let out = rt
        .call("awa_snapshot_m4_d50", &[&means, &counts_f, &[k_t]])
        .expect("awa_snapshot");

    // Expected: pooled recent + γ⁰ correction (same math as AwaMulti).
    let n0 = counts[0] as f64;
    let nrec: f64 = counts[1..].iter().sum::<u64>() as f64;
    assert!(nrec > 0.0, "test needs a nonempty recent group");
    let disc = (1.0 / (n0 * k_t as f64) + 1.0 / (nrec * k_t as f64)
        - 1.0 / (n0 * nrec))
        .max(0.0);
    let gamma = ((nrec + n0 * nrec * disc.sqrt()) / (n0 + nrec)).clamp(0.0, 1.0);
    for i in 0..d {
        let mut pooled = 0.0f64;
        for j in 1..m {
            pooled += (counts[j] as f64 / nrec) * means[j * d + i] as f64;
        }
        let want = gamma * pooled + (1.0 - gamma) * means[i] as f64;
        assert!(
            (out[0][i] as f64 - want).abs() < 1e-4,
            "i={i}: pjrt {} vs rust {want}",
            out[0][i]
        );
    }
    // And the Rust averager value itself is finite and plausible.
    assert!(rust_value.iter().all(|v| v.is_finite()));
}
