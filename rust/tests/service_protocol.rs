//! Integration: TCP server + client over localhost — both protocol
//! generations, the cross-version matrix, pipelining, and multi_push.

use ata::config::BackpressurePolicy;
use ata::coordinator::protocol::{
    self, wire, MultiOutcome, OpKind, ProtocolChoice, Request, Response, StreamRef, Wire,
};
use ata::coordinator::{Client, ClientError, Coordinator, Server, ServerOptions};
use ata::util::json::Json;
use std::net::TcpStream;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn start_server() -> (Server, String) {
    start_server_with(ProtocolChoice::Auto)
}

fn start_server_with(choice: ProtocolChoice) -> (Server, String) {
    let c = Arc::new(Coordinator::new(2, 256, BackpressurePolicy::Block));
    let server = Server::start_with("127.0.0.1:0", c, 4, choice).expect("server");
    let addr = server.addr().to_string();
    (server, addr)
}

/// A raw protocol-v2 connection: does the hello handshake by hand and
/// moves byte-level frames — the tests that must see the wire itself.
struct RawV2 {
    s: TcpStream,
    buf: Vec<u8>,
}

impl RawV2 {
    fn connect(addr: &str) -> RawV2 {
        let mut s = TcpStream::connect(addr).expect("raw connect");
        s.set_nodelay(true).unwrap();
        wire::write_frame_bytes(&mut s, &protocol::hello_frame(protocol::WIRE_V2))
            .expect("send hello");
        let mut buf = Vec::new();
        wire::read_frame_into(&mut s, &mut buf)
            .expect("hello ack io")
            .expect("hello ack frame");
        assert_eq!(
            protocol::parse_hello(&buf),
            Some(protocol::WIRE_V2),
            "server must commit to v2"
        );
        RawV2 { s, buf }
    }

    fn send(&mut self, seq: u64, req: &Request) {
        self.send_traced(seq, 0, req);
    }

    fn send_traced(&mut self, seq: u64, trace: u64, req: &Request) {
        protocol::encode_request(Wire::V2Binary, seq, trace, req, &mut self.buf).expect("encode");
        wire::write_frame_bytes(&mut self.s, &self.buf).expect("send frame");
    }

    fn send_raw(&mut self, payload: &[u8]) {
        wire::write_frame_bytes(&mut self.s, payload).expect("send raw frame");
    }

    fn recv(&mut self, kind: OpKind) -> (u64, Response) {
        let (seq, _trace, resp) = self.recv_traced(kind);
        (seq, resp)
    }

    fn recv_traced(&mut self, kind: OpKind) -> (u64, u64, Response) {
        wire::read_frame_into(&mut self.s, &mut self.buf)
            .expect("recv io")
            .expect("recv frame");
        protocol::decode_response(Wire::V2Binary, kind, &self.buf).expect("decode response")
    }
}

/// The original end-to-end workflow, reusable across protocol
/// generations so the legacy suite literally runs on both.
fn full_workflow(cl: &mut Client) {
    cl.ping().expect("ping");

    cl.register("layer0", 4, "awa3(c=0.5)").expect("register");
    cl.register("bn", 2, "gea(c=0.25)").expect("register");
    let mut names = cl.list_streams().expect("list");
    names.sort();
    assert_eq!(names, vec!["bn".to_string(), "layer0".to_string()]);

    for t in 1..=100u64 {
        assert!(cl.push("layer0", &[t as f64; 4]).expect("push"));
        assert!(cl.push("bn", &[t as f64, -(t as f64)]).expect("push"));
    }
    cl.sync().expect("sync");

    let snap = cl.snapshot("layer0").expect("snapshot");
    assert_eq!(snap.t, 100);
    assert_eq!(snap.value.as_ref().unwrap().len(), 4);
    assert!(snap.window_len > 0.0);

    let metrics = cl.metrics().expect("metrics");
    assert_eq!(
        metrics
            .get("streams")
            .and_then(|s| s.as_arr())
            .map(<[_]>::len),
        Some(2)
    );
}

#[test]
fn full_client_workflow_negotiates_v2_by_default() {
    let (_server, addr) = start_server();
    let mut cl = Client::connect(&addr).expect("connect");
    assert_eq!(
        cl.protocol_version(),
        2,
        "the binary protocol must be the default client↔server codec"
    );
    full_workflow(&mut cl);
}

#[test]
fn full_client_workflow_on_legacy_v1() {
    // The legacy suite, unchanged, over the legacy codec (no hello).
    let (_server, addr) = start_server();
    let mut cl = Client::connect_with(&addr, ProtocolChoice::V1).expect("connect");
    assert_eq!(cl.protocol_version(), 1);
    full_workflow(&mut cl);
}

#[test]
fn register_returns_handles_and_resolve_matches() {
    let (_server, addr) = start_server();
    let mut cl = Client::connect(&addr).expect("connect");
    let h = cl.register("w", 3, "gea(c=0.5)").expect("register");
    assert!(h > 0);
    assert_eq!(cl.resolve("w").expect("resolve"), h);
    // The v2 directory pairs names with handles and dims.
    let infos = cl.list_streams_full().expect("list");
    assert_eq!(infos.len(), 1);
    assert_eq!((infos[0].handle, infos[0].dim), (h, 3));
    let err = cl.resolve("ghost").unwrap_err();
    assert!(err.to_string().contains("ghost"), "{err}");
}

#[test]
fn server_reports_errors_not_disconnects() {
    let (_server, addr) = start_server();
    let mut cl = Client::connect(&addr).expect("connect");

    // Unknown stream
    let err = cl.push("ghost", &[1.0]).unwrap_err().to_string();
    assert!(err.contains("ghost"), "{err}");
    // Bad spec
    let err = cl.register("x", 2, "bogus(c=1)").unwrap_err().to_string();
    assert!(err.contains("bogus"), "{err}");
    // Wrong dims
    cl.register("x", 2, "gea(c=0.5)").unwrap();
    let err = cl.push("x", &[1.0]).unwrap_err().to_string();
    assert!(err.contains("dims"), "{err}");
    // Duplicate register
    let err = cl.register("x", 2, "gea(c=0.5)").unwrap_err().to_string();
    assert!(err.contains("already"), "{err}");
    // Connection still healthy afterwards.
    cl.ping().expect("connection survives errors");
}

#[test]
fn multiple_concurrent_clients() {
    let (_server, addr) = start_server();
    let mut setup = Client::connect(&addr).unwrap();
    setup.register("shared", 1, "true(k=1)").unwrap();
    drop(setup);

    let mut handles = Vec::new();
    for i in 0..4 {
        let addr = addr.clone();
        handles.push(std::thread::spawn(move || {
            let mut cl = Client::connect(&addr).unwrap();
            for t in 0..250 {
                cl.push("shared", &[(i * 1000 + t) as f64]).unwrap();
            }
            cl.sync().unwrap();
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let mut cl = Client::connect(&addr).unwrap();
    let snap = cl.snapshot("shared").unwrap();
    assert_eq!(snap.t, 1000);
}

#[test]
fn push_many_batches_apply_in_order() {
    let (_server, addr) = start_server();
    let mut cl = Client::connect(&addr).unwrap();
    cl.register("batch", 2, "true(k=1)").unwrap();
    // 100 samples in one round-trip; true(k=1) keeps only the last.
    let mut flat = Vec::with_capacity(200);
    for i in 1..=100u64 {
        flat.push(i as f64);
        flat.push(-(i as f64));
    }
    let (accepted, dropped) = cl.push_many("batch", 100, &flat).unwrap();
    assert_eq!((accepted, dropped), (100, 0));
    cl.sync().unwrap();
    let snap = cl.snapshot("batch").unwrap();
    assert_eq!(snap.t, 100);
    assert_eq!(snap.value.unwrap(), vec![100.0, -100.0]);
}

#[test]
fn push_many_rejects_wrong_dim() {
    let (_server, addr) = start_server();
    let mut cl = Client::connect(&addr).unwrap();
    cl.register("b", 3, "gea(c=0.5)").unwrap();
    // 10 floats, count 5 → dim 2 != 3.
    let err = cl.push_many("b", 5, &[0.0; 10]).unwrap_err().to_string();
    assert!(err.contains("dims"), "{err}");
    cl.ping().unwrap();
}

#[test]
fn push_many_zero_count_and_ragged_get_structured_error_frames() {
    use ata::coordinator::protocol::{read_frame, write_frame};
    let (_server, addr) = start_server();
    {
        let mut cl = Client::connect(&addr).expect("connect");
        cl.register("w", 2, "gea(c=0.5)").unwrap();
    }
    // Drive the legacy JSON wire directly so malformed batches actually
    // cross the server round-trip (the Client would pre-validate). No
    // hello: the server must auto-detect a legacy peer.
    let mut raw = TcpStream::connect(&addr).expect("raw connect");
    raw.set_nodelay(true).unwrap();
    for (count, data_len) in [(0.0, 0usize), (0.0, 4), (3.0, 4)] {
        let req = Json::obj(vec![
            ("op", Json::Str("push_many".into())),
            ("stream", Json::Str("w".into())),
            ("count", Json::Num(count)),
            ("data", Json::nums(&vec![1.0; data_len])),
        ]);
        write_frame(&mut raw, &req).unwrap();
        let resp = read_frame(&mut raw).unwrap().expect("response frame");
        assert_eq!(
            resp.get("ok").and_then(Json::as_bool),
            Some(false),
            "count={count} len={data_len} must be an error frame: {resp:?}"
        );
        let err = resp.get("error").and_then(Json::as_str).unwrap();
        assert!(err.contains("do not split"), "{err}");
    }
    // A batch whose shape is self-consistent but wrong for the stream's
    // declared dim is also a structured error, not a disconnect.
    let req = protocol::v1::request_to_json(&Request::PushMany {
        stream: StreamRef::Name("w".into()),
        count: 2,
        data: vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0], // dim 3 != 2
    })
    .unwrap();
    write_frame(&mut raw, &req).unwrap();
    let resp = read_frame(&mut raw).unwrap().unwrap();
    assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(false));
    assert!(resp
        .get("error")
        .and_then(Json::as_str)
        .unwrap()
        .contains("dims"));
    // Connection still healthy afterwards; nothing was applied.
    write_frame(&mut raw, &protocol::v1::request_to_json(&Request::Ping).unwrap()).unwrap();
    let pong = read_frame(&mut raw).unwrap().unwrap();
    assert_eq!(pong.get("ok").and_then(Json::as_bool), Some(true));
    let mut cl = Client::connect(&addr).unwrap();
    cl.sync().unwrap();
    assert_eq!(cl.snapshot("w").unwrap().t, 0);
}

#[test]
fn push_many_batched_path_matches_per_sample_path() {
    let (_server, addr) = start_server();
    let mut cl = Client::connect(&addr).unwrap();
    cl.register("batched", 3, "awa3(c=0.5)").unwrap();
    cl.register("single", 3, "awa3(c=0.5)").unwrap();
    let mut flat = Vec::new();
    for i in 1..=60u64 {
        flat.extend_from_slice(&[i as f64, (i as f64).sqrt(), -(i as f64)]);
    }
    // Mixed batch sizes through the wire, vs one-at-a-time pushes.
    let (a1, _) = cl.push_many("batched", 1, &flat[..3]).unwrap();
    let (a2, _) = cl.push_many("batched", 9, &flat[3..30]).unwrap();
    let (a3, _) = cl.push_many("batched", 50, &flat[30..]).unwrap();
    assert_eq!(a1 + a2 + a3, 60);
    for chunk in flat.chunks_exact(3) {
        cl.push("single", chunk).unwrap();
    }
    cl.sync().unwrap();
    let a = cl.snapshot("batched").unwrap();
    let b = cl.snapshot("single").unwrap();
    assert_eq!(a.t, 60);
    assert_eq!(b.t, 60);
    let (va, vb) = (a.value.unwrap(), b.value.unwrap());
    for i in 0..3 {
        assert!(
            (va[i] - vb[i]).abs() < 1e-12,
            "dim {i}: batched {} vs single {}",
            va[i],
            vb[i]
        );
    }
}

#[test]
fn multi_push_matches_per_stream_push_many_over_the_wire() {
    let (_server, addr) = start_server();
    let mut cl = Client::connect(&addr).unwrap();
    assert_eq!(cl.protocol_version(), 2);
    let d = 3;
    for i in 0..6 {
        cl.register(&format!("m{i}"), d, "awa3(c=0.5)").unwrap();
        cl.register(&format!("r{i}"), d, "awa3(c=0.5)").unwrap();
    }
    let batch = |i: usize| -> Vec<f64> {
        (0..8 * d)
            .map(|k| ((i * 97 + k) as f64 * 0.173).sin() * 2.0)
            .collect()
    };
    let batches: Vec<Vec<f64>> = (0..6).map(batch).collect();
    let names: Vec<String> = (0..6).map(|i| format!("m{i}")).collect();
    let multi: Vec<(&str, usize, &[f64])> = (0..6)
        .map(|i| (names[i].as_str(), 8, batches[i].as_slice()))
        .collect();
    // ONE frame for all six streams…
    let outcomes = cl.multi_push(&multi).expect("multi_push");
    assert_eq!(outcomes, vec![MultiOutcome::Accepted; 6]);
    // …vs one push_many per twin stream.
    for i in 0..6 {
        cl.push_many(&format!("r{i}"), 8, &batches[i]).unwrap();
    }
    cl.sync().unwrap();
    for i in 0..6 {
        let a = cl.snapshot(&format!("m{i}")).unwrap();
        let b = cl.snapshot(&format!("r{i}")).unwrap();
        assert_eq!(a.t, 8);
        assert_eq!(a.t, b.t);
        let (va, vb) = (a.value.unwrap(), b.value.unwrap());
        for k in 0..d {
            assert!(
                (va[k] - vb[k]).abs() < 1e-12,
                "stream {i} dim {k}: {} vs {}",
                va[k],
                vb[k]
            );
        }
    }
    // Entries fail independently: an unknown name rejects only itself
    // (same per-entry semantics as the v1 degradation), siblings apply.
    let bogus: Vec<(&str, usize, &[f64])> = vec![
        ("m0", 8, batches[0].as_slice()),
        ("nope", 8, batches[1].as_slice()),
    ];
    let outcomes = cl.multi_push(&bogus).expect("per-entry rejection, not an abort");
    assert_eq!(outcomes[0], MultiOutcome::Accepted);
    assert!(
        matches!(&outcomes[1], MultiOutcome::Rejected(e) if e.contains("nope")),
        "{outcomes:?}"
    );
    cl.sync().unwrap();
    assert_eq!(cl.snapshot("m0").unwrap().t, 16, "the good entry applied");
    cl.ping().unwrap();
}

#[test]
fn multi_push_degrades_gracefully_on_v1() {
    let (_server, addr) = start_server_with(ProtocolChoice::V1);
    let mut cl = Client::connect(&addr).unwrap();
    assert_eq!(cl.protocol_version(), 1);
    cl.register("a", 1, "gea(c=0.5)").unwrap();
    cl.register("b", 1, "gea(c=0.5)").unwrap();
    let xs = [1.0, 2.0, 3.0];
    let outcomes = cl
        .multi_push(&[("a", 3, &xs[..]), ("b", 3, &xs[..])])
        .expect("multi_push degrades to per-stream round-trips");
    assert_eq!(outcomes, vec![MultiOutcome::Accepted; 2]);
    cl.sync().unwrap();
    assert_eq!(cl.snapshot("a").unwrap().t, 3);
    assert_eq!(cl.snapshot("b").unwrap().t, 3);
}

#[test]
fn byte_level_v2_roundtrips_over_tcp() {
    let (_server, addr) = start_server();
    let mut raw = RawV2::connect(&addr);
    // Register → handle, all at the frame level.
    raw.send(
        7,
        &Request::Register {
            stream: "w".into(),
            dim: 2,
            spec: "gea(c=0.5)".into(),
        },
    );
    let (seq, resp) = raw.recv(OpKind::Register);
    assert_eq!(seq, 7);
    let Response::Registered { handle } = resp else {
        panic!("expected Registered, got {resp:?}");
    };
    assert!(handle > 0);
    // Handle-addressed batched push with exact little-endian f64s.
    raw.send(
        8,
        &Request::PushMany {
            stream: StreamRef::Handle(handle),
            count: 3,
            data: vec![1.5, -2.5, 3.25, -4.75, 0.125, 9.0],
        },
    );
    assert_eq!(
        raw.recv(OpKind::PushMany),
        (
            8,
            Response::PushedMany {
                accepted: 3,
                dropped: 0
            }
        )
    );
    raw.send(9, &Request::Sync);
    assert_eq!(raw.recv(OpKind::Sync), (9, Response::Synced));
    raw.send(10, &Request::Snapshot {
        stream: StreamRef::Handle(handle),
    });
    let (seq, resp) = raw.recv(OpKind::Snapshot);
    assert_eq!(seq, 10);
    let Response::Snap { stream, t, value, .. } = resp else {
        panic!("expected Snap, got {resp:?}");
    };
    assert_eq!(stream, "w");
    assert_eq!(t, 3);
    assert_eq!(value.expect("value").len(), 2);
    // A stale/unknown handle is a structured per-request error.
    raw.send(11, &Request::Snapshot {
        stream: StreamRef::Handle(handle + 999),
    });
    let (seq, resp) = raw.recv(OpKind::Snapshot);
    assert_eq!(seq, 11);
    assert!(matches!(resp, Response::Err(e) if e.contains("handle")));
    // Binary state transfer: raw bytes on the wire, no hex.
    raw.send(12, &Request::ExportState {
        stream: StreamRef::Handle(handle),
    });
    let (_, resp) = raw.recv(OpKind::ExportState);
    let Response::State { state, .. } = resp else {
        panic!("expected State, got {resp:?}");
    };
    assert_eq!(&state[..4], b"ATAE", "framed state payload travels raw");
}

#[test]
fn pipelined_requests_complete_out_of_order() {
    // A sync barrier behind a deep apply backlog must NOT stall the
    // pipelined ping sent after it: the ping's response arrives first,
    // matched by id. Determinism: ONE multi-million-sample batch is
    // enqueued as a single shard message, so the barrier message queued
    // behind it cannot be acked before the whole batch applies
    // (milliseconds of estimator work), while the inline ping answers
    // in microseconds.
    const N: usize = 4_000_000;
    let c = Arc::new(Coordinator::new(1, 64, BackpressurePolicy::Block));
    let server = Server::start("127.0.0.1:0", c, 4).expect("server");
    let addr = server.addr().to_string();
    {
        let mut cl = Client::connect(&addr).unwrap();
        cl.register("big", 1, "gea(c=0.5)").unwrap();
    }
    let mut raw = RawV2::connect(&addr);
    raw.send(1, &Request::Resolve {
        stream: "big".into(),
    });
    let (_, resp) = raw.recv(OpKind::Resolve);
    let Response::Resolved { handle, .. } = resp else {
        panic!("expected Resolved, got {resp:?}");
    };
    raw.send(100, &Request::PushMany {
        stream: StreamRef::Handle(handle),
        count: N,
        data: vec![0.5; N],
    });
    // Pipeline the barrier and a ping behind it WITHOUT reading acks.
    raw.send(500, &Request::Sync);
    raw.send(501, &Request::Ping);
    // Collect all 3 responses; the ping must overtake the sync.
    let mut order: Vec<u64> = Vec::new();
    for _ in 0..3 {
        wire::read_frame_into(&mut raw.s, &mut raw.buf)
            .expect("recv io")
            .expect("recv frame");
        // Peek the seq, then decode with the right op kind.
        let seq = u64::from_le_bytes(raw.buf[..8].try_into().unwrap());
        let kind = match seq {
            100 => OpKind::PushMany,
            500 => OpKind::Sync,
            501 => OpKind::Ping,
            other => panic!("unexpected seq {other}"),
        };
        let (got, _trace, resp) = protocol::decode_response(Wire::V2Binary, kind, &raw.buf).unwrap();
        assert_eq!(got, seq);
        match seq {
            100 => assert_eq!(
                resp,
                Response::PushedMany {
                    accepted: N as u64,
                    dropped: 0
                }
            ),
            500 => assert_eq!(resp, Response::Synced),
            _ => assert_eq!(resp, Response::Pong),
        }
        order.push(seq);
    }
    let ping_at = order.iter().position(|&s| s == 501).unwrap();
    let sync_at = order.iter().position(|&s| s == 500).unwrap();
    assert!(
        ping_at < sync_at,
        "ping (seq 501) must complete before the sync barrier (seq 500): {order:?}"
    );
    // And the barrier really waited: everything is applied.
    raw.send(502, &Request::Snapshot {
        stream: StreamRef::Handle(handle),
    });
    let (_, resp) = raw.recv(OpKind::Snapshot);
    let Response::Snap { t, .. } = resp else {
        panic!("expected Snap, got {resp:?}");
    };
    assert_eq!(t, N as u64);
}

#[test]
fn client_pipelined_push_many_matches_sequential() {
    let (_server, addr) = start_server();
    let mut cl = Client::connect(&addr).unwrap();
    cl.register("pipe", 2, "awa3(c=0.5)").unwrap();
    cl.register("seq", 2, "awa3(c=0.5)").unwrap();
    let chunks: Vec<Vec<f64>> = (0..10)
        .map(|i| (0..12).map(|k| ((i * 12 + k) as f64 * 0.41).cos()).collect())
        .collect();
    let batches: Vec<(&str, usize, &[f64])> =
        chunks.iter().map(|c| ("pipe", 6, c.as_slice())).collect();
    let acks = cl.push_many_pipelined(&batches).expect("pipelined");
    assert_eq!(acks, vec![(6, 0); 10]);
    for c in &chunks {
        cl.push_many("seq", 6, c).unwrap();
    }
    cl.sync().unwrap();
    let a = cl.snapshot("pipe").unwrap();
    let b = cl.snapshot("seq").unwrap();
    assert_eq!(a.t, 60);
    assert_eq!(b.t, 60);
    let (va, vb) = (a.value.unwrap(), b.value.unwrap());
    for k in 0..2 {
        assert!((va[k] - vb[k]).abs() < 1e-12, "dim {k}");
    }
    // The pipelined API also runs on v1 (positional matching).
    let mut v1 = Client::connect_with(&addr, ProtocolChoice::V1).unwrap();
    let acks = v1.push_many_pipelined(&batches).expect("v1 pipelined");
    assert_eq!(acks, vec![(6, 0); 10]);
    v1.sync().unwrap();
    assert_eq!(v1.snapshot("pipe").unwrap().t, 120);
}

// ---------------------------------------------------------------------------
// Cross-version compatibility matrix
// ---------------------------------------------------------------------------

#[test]
fn v2_client_against_v1_only_server_falls_back() {
    let (_server, addr) = start_server_with(ProtocolChoice::V1);
    // Auto client: hello answered with v1 → transparent fallback.
    let mut cl = Client::connect(&addr).expect("connect");
    assert_eq!(cl.protocol_version(), 1);
    full_workflow(&mut cl);
    // A client REQUIRING v2 fails loudly instead of downgrading.
    let err = Client::connect_with(&addr, ProtocolChoice::V2).unwrap_err();
    assert!(matches!(err, ClientError::Protocol(_)), "{err}");
}

#[test]
fn v1_client_against_v2_default_server_works() {
    let (_server, addr) = start_server();
    let mut cl = Client::connect_with(&addr, ProtocolChoice::V1).expect("connect");
    assert_eq!(cl.protocol_version(), 1);
    full_workflow(&mut cl);
}

#[test]
fn missing_hello_legacy_peer_is_auto_detected() {
    use ata::coordinator::protocol::{read_frame, write_frame};
    let (_server, addr) = start_server();
    let mut raw = TcpStream::connect(&addr).expect("raw connect");
    raw.set_nodelay(true).unwrap();
    // First frame is a bare legacy JSON request — no hello at all.
    write_frame(
        &mut raw,
        &protocol::v1::request_to_json(&Request::Ping).unwrap(),
    )
    .unwrap();
    let pong = read_frame(&mut raw).unwrap().expect("pong frame");
    assert_eq!(pong.get("ok").and_then(Json::as_bool), Some(true));
    assert_eq!(pong.get("pong").and_then(Json::as_bool), Some(true));
    // The whole connection stays v1.
    write_frame(
        &mut raw,
        &protocol::v1::request_to_json(&Request::ListStreams).unwrap(),
    )
    .unwrap();
    let resp = read_frame(&mut raw).unwrap().expect("list frame");
    assert!(resp.get("streams").is_some());
}

#[test]
fn strict_v2_server_rejects_legacy_json_peers_readably() {
    use ata::coordinator::protocol::{read_frame, write_frame};
    let (_server, addr) = start_server_with(ProtocolChoice::V2);
    // A v2 client is fine…
    let mut cl = Client::connect(&addr).expect("connect");
    assert_eq!(cl.protocol_version(), 2);
    cl.ping().unwrap();
    // …a legacy JSON peer gets ONE structured JSON error, then EOF.
    let mut raw = TcpStream::connect(&addr).expect("raw connect");
    write_frame(
        &mut raw,
        &protocol::v1::request_to_json(&Request::Ping).unwrap(),
    )
    .unwrap();
    let resp = read_frame(&mut raw).unwrap().expect("error frame");
    assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(false));
    assert!(resp
        .get("error")
        .and_then(Json::as_str)
        .unwrap()
        .contains("hello"));
    // Server closes after rejecting: clean EOF or a reset, never a
    // further response frame.
    assert!(
        matches!(read_frame(&mut raw), Ok(None) | Err(_)),
        "server closes after rejecting a legacy peer in strict v2 mode"
    );
}

#[test]
fn mid_connection_garbage_after_handshake_is_survivable() {
    let (_server, addr) = start_server();
    let mut raw = RawV2::connect(&addr);
    // Garbage too short to even carry a seq: error echoed with seq 0.
    raw.send_raw(&[0xFF; 5]);
    let (seq, resp) = raw.recv(OpKind::Ping);
    assert_eq!(seq, 0);
    assert!(matches!(resp, Response::Err(_)), "{resp:?}");
    // Garbage with a readable seq header: the seq is echoed so a
    // pipelined client can fail just that request.
    let mut junk = 77u64.to_le_bytes().to_vec();
    junk.extend_from_slice(&[0xEE, 0xDD, 0xCC]);
    raw.send_raw(&junk);
    let (seq, resp) = raw.recv(OpKind::Ping);
    assert_eq!(seq, 77);
    assert!(matches!(resp, Response::Err(_)), "{resp:?}");
    // Framing never desynchronized: a real request still works.
    raw.send(9, &Request::Ping);
    assert_eq!(raw.recv(OpKind::Ping), (9, Response::Pong));
}

#[test]
fn snapshot_of_empty_stream_has_null_value() {
    let (_server, addr) = start_server();
    let mut cl = Client::connect(&addr).unwrap();
    cl.register("empty", 3, "gea(c=0.5)").unwrap();
    let snap = cl.snapshot("empty").unwrap();
    assert_eq!(snap.t, 0);
    assert!(snap.value.is_none());
}

#[test]
fn server_shutdown_is_clean() {
    let (mut server, addr) = start_server();
    let mut cl = Client::connect(&addr).unwrap();
    cl.ping().unwrap();
    server.shutdown();
    // New connections must fail after shutdown... the listener socket is
    // closed; allow either immediate failure or failure on first use.
    match Client::connect(&addr) {
        Err(_) => {}
        Ok(mut c2) => {
            let _ = c2.set_timeout(Some(std::time::Duration::from_millis(200)));
            assert!(c2.ping().is_err());
        }
    }
}

#[test]
fn state_transfer_ops_over_the_wire() {
    // export_state → restore moves a stream's estimator state between
    // two independent servers; merge_state rolls a partial in. Runs on
    // the default (v2) codec: state bytes travel raw, handle-addressed.
    let (_sa, addr_a) = start_server();
    let (_sb, addr_b) = start_server();
    let mut ca = Client::connect(&addr_a).expect("connect a");
    let mut cb = Client::connect(&addr_b).expect("connect b");
    for cl in [&mut ca, &mut cb] {
        cl.register("w", 2, "gea(c=0.5)").unwrap();
        cl.register("tw", 1, "true(k=3)").unwrap();
    }
    let flat: Vec<f64> = (0..40).map(|i| (i as f64 * 0.3).sin()).collect();
    ca.push_many("w", 20, &flat).unwrap();
    ca.sync().unwrap();
    // Banked stream state over the wire.
    let state = ca.export_state("w").expect("export");
    assert!(!state.is_empty());
    assert_eq!(cb.restore("w", &state).expect("restore"), 20);
    let (sa, sb) = (ca.snapshot("w").unwrap(), cb.snapshot("w").unwrap());
    assert_eq!(sa.t, sb.t);
    assert_eq!(sa.value.unwrap(), sb.value.unwrap());
    // Slot-backed stream too.
    for t in 1..=5u64 {
        ca.push("tw", &[t as f64]).unwrap();
    }
    ca.sync().unwrap();
    let state = ca.export_state("tw").expect("export tw");
    assert_eq!(cb.restore("tw", &state).expect("restore tw"), 5);
    // merge_state: a longer 'true' window takes precedence.
    for t in 1..=9u64 {
        cb.push("tw", &[100.0 + t as f64]).unwrap();
    }
    cb.sync().unwrap();
    let partial = cb.export_state("tw").unwrap();
    assert_eq!(ca.merge_state("tw", &partial).expect("merge"), 14);
    // Corrupt payloads come back as structured errors, not disconnects.
    let err = ca.restore("w", b"junk").unwrap_err();
    assert!(matches!(err, ClientError::Server(_)), "{err:?}");
    ca.ping().expect("connection still alive");
}

#[test]
fn checkpoint_op_requires_persist_and_works_with_it() {
    use ata::config::{PersistConfig, ServiceConfig};
    // Without a [persist] section the op is a structured error.
    let (_server, addr) = start_server();
    let mut cl = Client::connect(&addr).expect("connect");
    let err = cl.checkpoint().unwrap_err().to_string();
    assert!(err.contains("persist"), "{err}");
    cl.ping().expect("still alive");
    // With one, the snapshot lands on disk and reports its streams.
    let dir = ata::testkit::temp_dir("svc-checkpoint");
    let cfg = ServiceConfig {
        shards: 2,
        persist: Some(PersistConfig {
            dir: dir.display().to_string(),
            ..Default::default()
        }),
        ..Default::default()
    };
    let c = Arc::new(Coordinator::from_config(&cfg).unwrap());
    let server = Server::start("127.0.0.1:0", c, 2).expect("server");
    let mut cl = Client::connect(&server.addr().to_string()).expect("connect");
    cl.register("w", 2, "gea(c=0.5)").unwrap();
    cl.push_many("w", 4, &[1.0; 8]).unwrap();
    cl.sync().unwrap();
    let (path, streams) = cl.checkpoint().expect("checkpoint");
    assert_eq!(streams, 1);
    assert!(std::path::Path::new(&path).exists(), "{path}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn stale_cached_handles_recover_after_reregistration() {
    // Server-side unregister + re-register mints a fresh handle; a v2
    // client holding the old one in its cache must transparently
    // re-resolve instead of failing forever.
    let c = Arc::new(Coordinator::new(1, 64, BackpressurePolicy::Block));
    let server = Server::start("127.0.0.1:0", Arc::clone(&c), 2).expect("server");
    let mut cl = Client::connect(&server.addr().to_string()).unwrap();
    let h1 = cl.register("w", 1, "gea(c=0.5)").unwrap();
    assert!(cl.push("w", &[1.0]).unwrap());
    // Churn the stream behind the client's back.
    c.unregister("w").unwrap();
    let h2 = c.register("w", 1, ata::averagers::AveragerSpec::Gea { c: 0.5 }).unwrap();
    assert_ne!(h1, h2);
    // Every handle-addressed op recovers via one re-resolve.
    assert!(cl.push("w", &[2.0]).unwrap());
    cl.sync().unwrap();
    assert_eq!(cl.snapshot("w").unwrap().t, 1); // fresh stream: only the retried push
    assert_eq!(cl.push_many("w", 2, &[3.0, 4.0]).unwrap(), (2, 0));
    cl.sync().unwrap();
    assert_eq!(cl.snapshot("w").unwrap().t, 3);
    // A genuinely missing stream still errors (no infinite retries).
    c.unregister("w").unwrap();
    assert!(cl.push("w", &[5.0]).is_err());
}

// ---------------------------------------------------------------------------
// Anytime analytics over the wire: query / multi_snapshot, both codecs
// ---------------------------------------------------------------------------

/// Seed a server with banked + slot streams carrying known data.
fn seed_analytics_server() -> (Server, String) {
    let (server, addr) = start_server();
    let mut cl = Client::connect(&addr).expect("connect");
    cl.register("q/gea", 2, "gea(c=0.5)").unwrap();
    cl.register("q/awa", 2, "awa3(c=0.5)").unwrap();
    cl.register("q/true", 2, "true(k=8)").unwrap();
    cl.register("other", 1, "gea(c=0.5)").unwrap();
    for (i, name) in ["q/gea", "q/awa", "q/true"].iter().enumerate() {
        let flat: Vec<f64> = (0..50 * 2)
            .map(|k| ((i * 131 + k) as f64 * 0.217).sin() * 2.0 + i as f64)
            .collect();
        cl.push_many(name, 50, &flat).unwrap();
    }
    cl.sync().unwrap();
    (server, addr)
}

#[test]
fn query_returns_identical_results_over_v1_and_v2() {
    let (_server, addr) = seed_analytics_server();
    let mut v2 = Client::connect(&addr).expect("v2");
    let mut v1 = Client::connect_with(&addr, ProtocolChoice::V1).expect("v1");
    assert_eq!(v2.protocol_version(), 2);
    assert_eq!(v1.protocol_version(), 1);
    for (top_k, aggregate) in [(0u64, false), (0, true), (2, true)] {
        let (s2, a2) = v2.query("q/", 1.96, top_k, aggregate).expect("v2 query");
        let (s1, a1) = v1.query("q/", 1.96, top_k, aggregate).expect("v1 query");
        assert_eq!(s1.len(), s2.len(), "top_k={top_k}");
        for (e1, e2) in s1.iter().zip(&s2) {
            assert_eq!(e1.stream, e2.stream);
            assert_eq!(e1.t, e2.t);
            assert!((e1.ess - e2.ess).abs() <= 1e-12 * e2.ess.abs().max(1.0));
            for d in 0..e2.mean.len() {
                assert!(
                    (e1.mean[d] - e2.mean[d]).abs() <= 1e-12 * e2.mean[d].abs().max(1.0),
                    "{} mean[{d}]: v1 {} vs v2 {}",
                    e1.stream,
                    e1.mean[d],
                    e2.mean[d]
                );
                assert!(
                    (e1.variance[d] - e2.variance[d]).abs()
                        <= 1e-12 * e2.variance[d].abs().max(1.0),
                    "{} variance[{d}]",
                    e1.stream
                );
                assert!(
                    (e1.band[d] - e2.band[d]).abs() <= 1e-12 * e2.band[d].abs().max(1.0),
                    "{} band[{d}]",
                    e1.stream
                );
            }
        }
        match (a1, a2, aggregate) {
            (None, None, false) => {}
            (Some(a1), Some(a2), true) => {
                assert_eq!(a1.t, a2.t);
                for d in 0..a2.mean.len() {
                    assert!(
                        (a1.mean[d] - a2.mean[d]).abs()
                            <= 1e-12 * a2.mean[d].abs().max(1.0)
                    );
                }
            }
            (a1, a2, _) => panic!("aggregate presence differs: {a1:?} vs {a2:?}"),
        }
    }
    // The stat mean must equal the plain snapshot value, both codecs.
    for cl in [&mut v2, &mut v1] {
        let (stats, _) = cl.query("q/gea", 1.96, 0, false).unwrap();
        assert_eq!(stats.len(), 1);
        let snap = cl.snapshot("q/gea").unwrap();
        assert_eq!(stats[0].mean, &snap.value.unwrap()[..]);
        assert_eq!(stats[0].t, 50);
        assert!(stats[0].ess > 1.0);
        assert!(stats[0].variance.iter().all(|&v| v > 0.0));
    }
}

#[test]
fn multi_snapshot_matches_across_protocols_with_per_entry_errors() {
    let (_server, addr) = seed_analytics_server();
    let mut v2 = Client::connect(&addr).expect("v2");
    let mut v1 = Client::connect_with(&addr, ProtocolChoice::V1).expect("v1");
    let names = ["q/awa", "ghost", "q/true"];
    let r2 = v2.multi_snapshot(&names).expect("v2 multi_snapshot");
    let r1 = v1.multi_snapshot(&names).expect("v1 multi_snapshot");
    assert_eq!(r1.len(), 3);
    assert_eq!(r2.len(), 3);
    for (i, (e1, e2)) in r1.iter().zip(&r2).enumerate() {
        match (e1, e2) {
            (Ok(a), Ok(b)) => {
                assert_eq!(a.stream, b.stream);
                assert_eq!(a.t, b.t);
                for d in 0..b.mean.len() {
                    assert!(
                        (a.mean[d] - b.mean[d]).abs() <= 1e-12 * b.mean[d].abs().max(1.0),
                        "entry {i} mean[{d}]"
                    );
                    assert!(
                        (a.variance[d] - b.variance[d]).abs()
                            <= 1e-12 * b.variance[d].abs().max(1.0),
                        "entry {i} variance[{d}]"
                    );
                }
            }
            (Err(a), Err(b)) => {
                assert!(a.contains("ghost"), "{a}");
                assert!(b.contains("ghost"), "{b}");
            }
            (a, b) => panic!("entry {i} outcome differs: {a:?} vs {b:?}"),
        }
    }
    // Both connections stay healthy after the mixed-outcome frame.
    v2.ping().unwrap();
    v1.ping().unwrap();
}

#[test]
fn multi_snapshot_purges_stale_handles_per_entry() {
    let c = Arc::new(Coordinator::new(1, 64, BackpressurePolicy::Block));
    let server = Server::start("127.0.0.1:0", Arc::clone(&c), 2).expect("server");
    let mut cl = Client::connect(&server.addr().to_string()).unwrap();
    cl.register("w", 1, "gea(c=0.5)").unwrap();
    cl.push("w", &[4.0]).unwrap();
    cl.sync().unwrap();
    assert!(cl.multi_snapshot(&["w"]).unwrap()[0].is_ok());
    // Churn the stream server-side: the cached handle goes stale.
    c.unregister("w").unwrap();
    c.register("w", 1, ata::averagers::AveragerSpec::Gea { c: 0.5 })
        .unwrap();
    let out = cl.multi_snapshot(&["w"]).unwrap();
    assert!(
        matches!(&out[0], Err(e) if e.contains("handle")),
        "stale entry reported: {out:?}"
    );
    // The purge made the NEXT call re-resolve and succeed.
    let out = cl.multi_snapshot(&["w"]).unwrap();
    assert!(out[0].is_ok(), "{out:?}");
}

#[test]
fn wire_metrics_count_connections_and_frames() {
    let c = Arc::new(Coordinator::new(1, 64, BackpressurePolicy::Block));
    let server = Server::start("127.0.0.1:0", Arc::clone(&c), 2).expect("server");
    let addr = server.addr().to_string();
    {
        let mut v2 = Client::connect(&addr).unwrap();
        v2.ping().unwrap();
        let mut v1 = Client::connect_with(&addr, ProtocolChoice::V1).unwrap();
        v1.ping().unwrap();
    }
    let m = c.metrics();
    assert_eq!(m.counter("wire_connections_v2").get(), 1);
    assert_eq!(m.counter("wire_connections_v1").get(), 1);
    assert!(m.counter("wire_frames_in").get() >= 3);
    assert!(m.counter("wire_frames_out").get() >= 3);
}

// ---------------------------------------------------------------------------
// Survivability: graceful drain, admission gate, deadlines, and the
// half-closed-socket client regression
// ---------------------------------------------------------------------------

/// Graceful drain with live v1 and v2 producers mid-flight: every frame
/// the server read is answered and applied; every frame it never read
/// is cleanly refused (EOF — never a silent half-apply). The per-stream
/// applied counts must therefore equal the clients' acked counts
/// exactly, on both protocol generations at once.
#[test]
fn drain_settles_inflight_frames_on_both_protocols() {
    let c = Arc::new(Coordinator::new(2, 256, BackpressurePolicy::Block));
    let mut server =
        Server::start_with_options("127.0.0.1:0", Arc::clone(&c), 4, ServerOptions::default())
            .expect("server");
    let addr = server.addr().to_string();
    {
        let mut setup = Client::connect(&addr).unwrap();
        for s in ["drain/v1", "drain/v2a", "drain/v2b"] {
            setup.register(s, 1, "gea(c=0.5)").unwrap();
        }
    }
    // v1 producer: sequential push_many until the drain cuts it off.
    let v1_addr = addr.clone();
    let v1 = std::thread::spawn(move || -> u64 {
        let mut cl = match Client::connect_with(&v1_addr, ProtocolChoice::V1) {
            Ok(cl) => cl,
            Err(_) => return 0,
        };
        let mut acked = 0u64;
        loop {
            match cl.push_many("drain/v1", 3, &[1.0, 2.0, 3.0]) {
                Ok((accepted, _)) => acked += accepted,
                Err(_) => return acked,
            }
        }
    });
    // v2 producer: multi_push windows (two streams per frame).
    let v2_addr = addr.clone();
    let v2 = std::thread::spawn(move || -> (u64, u64) {
        let mut cl = match Client::connect_with(&v2_addr, ProtocolChoice::V2) {
            Ok(cl) => cl,
            Err(_) => return (0, 0),
        };
        let (mut a, mut b) = (0u64, 0u64);
        loop {
            let out = match cl.multi_push(&[
                ("drain/v2a", 2, &[1.0, 2.0][..]),
                ("drain/v2b", 2, &[3.0, 4.0][..]),
            ]) {
                Ok(out) => out,
                Err(_) => return (a, b),
            };
            if matches!(out[0], MultiOutcome::Accepted) {
                a += 2;
            }
            if matches!(out[1], MultiOutcome::Accepted) {
                b += 2;
            }
        }
    });
    std::thread::sleep(Duration::from_millis(40));
    server.drain(Duration::from_secs(5));
    let v1_acked = v1.join().expect("v1 producer");
    let (v2a_acked, v2b_acked) = v2.join().expect("v2 producer");
    // Drain already ran the sync barrier; the coordinator's applied
    // counts are final and must match the ack ledgers exactly.
    assert_eq!(c.snapshot("drain/v1").unwrap().t, v1_acked);
    assert_eq!(c.snapshot("drain/v2a").unwrap().t, v2a_acked);
    assert_eq!(c.snapshot("drain/v2b").unwrap().t, v2b_acked);
    assert!(
        v1_acked + v2a_acked + v2b_acked > 0,
        "producers never got going before the drain"
    );
    // The listener is gone: no new connections after drain.
    assert!(Client::connect(&addr).is_err() || {
        // A TIME_WAIT accept can sneak in on some kernels; a ping must
        // still fail against the stopped server.
        let mut cl = Client::connect(&addr).unwrap();
        cl.ping().is_err()
    });
}

/// The admission gate refuses connections beyond `max_connections`
/// (closed pre-handshake, counted) and frees capacity when a client
/// leaves.
#[test]
fn admission_gate_rejects_and_recovers_capacity() {
    let c = Arc::new(Coordinator::new(1, 64, BackpressurePolicy::Block));
    let server = Server::start_with_options(
        "127.0.0.1:0",
        Arc::clone(&c),
        2,
        ServerOptions {
            max_connections: 1,
            ..Default::default()
        },
    )
    .expect("server");
    let addr = server.addr().to_string();
    let mut first = Client::connect(&addr).expect("first connection admitted");
    first.ping().expect("ping");
    // Beyond the cap: the socket is closed before any handshake, so
    // connect (which awaits the hello ack) fails cleanly.
    let second = Client::connect(&addr);
    assert!(second.is_err(), "second connection must be refused");
    assert!(c.metrics().counter("wire_connections_rejected").get() >= 1);
    // Capacity returns once the admitted client hangs up.
    drop(first);
    let deadline = Instant::now() + Duration::from_secs(3);
    loop {
        if let Ok(mut cl) = Client::connect(&addr) {
            if cl.ping().is_ok() {
                break;
            }
        }
        assert!(
            Instant::now() < deadline,
            "capacity never freed after the admitted client left"
        );
        std::thread::sleep(Duration::from_millis(10));
    }
    drop(server);
}

/// A connection that goes quiet past the idle deadline is reaped (and
/// counted) instead of pinning a handler slot forever.
#[test]
fn idle_connections_are_reaped_by_the_deadline() {
    let c = Arc::new(Coordinator::new(1, 64, BackpressurePolicy::Block));
    let server = Server::start_with_options(
        "127.0.0.1:0",
        Arc::clone(&c),
        2,
        ServerOptions {
            read_timeout_ms: 40,
            idle_timeout_ms: 120,
            ..Default::default()
        },
    )
    .expect("server");
    let mut cl = Client::connect(&server.addr().to_string()).expect("client");
    cl.ping().expect("ping while fresh");
    // Go quiet for well past the idle deadline; the server must close.
    let deadline = Instant::now() + Duration::from_secs(5);
    std::thread::sleep(Duration::from_millis(400));
    loop {
        if cl.ping().is_err() {
            break;
        }
        assert!(Instant::now() < deadline, "idle connection never reaped");
        std::thread::sleep(Duration::from_millis(100));
    }
    assert!(c.metrics().counter("wire_deadline_closes").get() >= 1);
    drop(server);
}

/// Regression: a half-closed socket (peer accepts, then never answers)
/// must surface `ClientError::Io` via the read timeout instead of
/// blocking a pipelined read forever.
#[test]
fn client_read_timeout_surfaces_io_instead_of_hanging() {
    let listener = std::net::TcpListener::bind("127.0.0.1:0").expect("stub listener");
    let addr = listener.local_addr().unwrap().to_string();
    std::thread::spawn(move || {
        // Accept, read (so client writes succeed), answer nothing.
        if let Ok((mut s, _)) = listener.accept() {
            let mut sink = [0u8; 1024];
            use std::io::Read as _;
            while matches!(s.read(&mut sink), Ok(n) if n > 0) {}
        }
    });
    // V1 skips the hello round-trip, so connect succeeds against the
    // mute peer and the first real op is what must not hang.
    let mut cl = Client::connect_with(&addr, ProtocolChoice::V1).expect("connect");
    cl.set_timeout(Some(Duration::from_millis(200))).unwrap();
    let start = Instant::now();
    let err = cl.ping().expect_err("mute server must not look healthy");
    assert!(
        matches!(err, ClientError::Io(_)),
        "want Io timeout, got: {err}"
    );
    assert!(
        start.elapsed() < Duration::from_secs(5),
        "read returned only after {:?} — effectively a hang",
        start.elapsed()
    );
}

// ---------------------------------------------------------------------------
// Observability plane: introspect equivalence, trace echo, exported gauges
// ---------------------------------------------------------------------------

/// The `introspect` op must return the same structural report on both
/// protocol generations — the v1 JSON projection and the v2 binary
/// codec describe one coordinator. Only the timing-sensitive tails
/// (flight events, span log) may drift between the two calls.
#[test]
fn introspect_reports_match_across_protocols() {
    let (_server, addr) = seed_analytics_server();
    let mut v2 = Client::connect(&addr).expect("v2");
    let mut v1 = Client::connect_with(&addr, ProtocolChoice::V1).expect("v1");
    let r2 = v2.introspect().expect("v2 introspect");
    let r1 = v1.introspect().expect("v1 introspect");
    assert_eq!(r1.sample_per_mille, r2.sample_per_mille);
    assert_eq!(r1.shards.len(), r2.shards.len());
    for (a, b) in r1.shards.iter().zip(&r2.shards) {
        assert_eq!(a, b, "shard vitals must agree across codecs");
        assert_eq!(a.queue_depth, 0, "post-sync queues are empty");
    }
    assert_eq!(r1.banks, r2.banks);
    assert_eq!(r1.streams, r2.streams);
    let names: Vec<&str> = r2.streams.iter().map(|s| s.name.as_str()).collect();
    for want in ["q/gea", "q/awa", "q/true", "other"] {
        assert!(names.contains(&want), "{want} missing from {names:?}");
    }
    // The seeded pushes left real flight events behind, on both wires.
    assert!(!r2.events.is_empty(), "pushes must leave flight events");
    assert!(!r1.events.is_empty());
}

/// The trace_id stamped on a request comes back on its ack — byte-level
/// on v2 (success AND error responses), and through the client's
/// `last_trace_id` ledger on both generations.
#[test]
fn trace_ids_round_trip_in_acks_on_both_wires() {
    let (_server, addr) = start_server();
    let mut raw = RawV2::connect(&addr);
    let trace = 0xDEAD_BEEF_CAFE_F00Du64;
    raw.send_traced(
        3,
        trace,
        &Request::Register {
            stream: "t".into(),
            dim: 1,
            spec: "gea(c=0.5)".into(),
        },
    );
    let (seq, got, resp) = raw.recv_traced(OpKind::Register);
    assert_eq!((seq, got), (3, trace));
    let Response::Registered { handle } = resp else {
        panic!("expected Registered, got {resp:?}");
    };
    raw.send_traced(
        4,
        trace + 1,
        &Request::PushMany {
            stream: StreamRef::Handle(handle),
            count: 2,
            data: vec![1.0, 2.0],
        },
    );
    let (_, got, resp) = raw.recv_traced(OpKind::PushMany);
    assert_eq!(got, trace + 1);
    assert!(matches!(resp, Response::PushedMany { accepted: 2, .. }));
    // Error acks keep the trace too — that is what makes a failed
    // request greppable end to end.
    raw.send_traced(
        5,
        trace + 2,
        &Request::PushMany {
            stream: StreamRef::Handle(handle + 999),
            count: 1,
            data: vec![1.0],
        },
    );
    let (_, got, resp) = raw.recv_traced(OpKind::PushMany);
    assert_eq!(got, trace + 2);
    assert!(matches!(resp, Response::Err(_)));
    // Client level: every request mints a trace and the server's echo
    // lands in last_trace_id, on both protocol generations.
    for choice in [ProtocolChoice::V2, ProtocolChoice::V1] {
        let mut cl = Client::connect_with(&addr, choice).expect("connect");
        assert_eq!(cl.last_trace_id(), 0, "no echo before the first op");
        cl.push_many("t", 2, &[3.0, 4.0]).expect("push");
        assert_ne!(cl.last_trace_id(), 0, "{choice:?} ack must echo a trace");
    }
}

/// Regression: derived gauges (queue depth, bank occupancy, flight
/// events) must never read as boot-time zeros over the wire after real
/// activity — every metrics consumer routes through
/// `Coordinator::export_metrics`. The Prometheus projection must carry
/// the new observability families with the same refreshed values.
#[test]
fn exported_gauges_and_prometheus_text_reflect_activity() {
    let (_server, addr) = seed_analytics_server();
    let mut cl = Client::connect(&addr).expect("connect");
    let doc = cl.metrics().expect("metrics");
    let m = doc.get("metrics").expect("registry export");
    let gauge = |name: &str| {
        m.get(&format!("gauge.{name}"))
            .and_then(Json::as_f64)
            .unwrap_or_else(|| panic!("missing gauge {name}"))
    };
    assert!(
        gauge("flight_events") > 0.0,
        "pushes must leave flight events"
    );
    assert!(gauge("bank_rows") >= 1.0, "banked streams occupy rows");
    assert_eq!(
        gauge("queue_depth_total"),
        0.0,
        "post-sync queues are empty"
    );
    let text = cl.metrics_prometheus().expect("prom");
    for family in [
        "ata_stage_latency_ns",
        "ata_flight_events",
        "ata_queue_depth_total",
        "ata_bank_rows",
        "ata_trace_spans_sampled",
    ] {
        assert!(
            text.contains(&format!("# TYPE {family} ")),
            "family {family} missing from exposition:\n{text}"
        );
    }
    assert!(
        !text.contains("ata_flight_events 0\n"),
        "scrape saw a stale zero gauge:\n{text}"
    );
}
