//! Integration: TCP server + client over localhost.

use ata::config::BackpressurePolicy;
use ata::coordinator::{Client, Coordinator, Server};
use std::sync::Arc;

fn start_server() -> (Server, String) {
    let c = Arc::new(Coordinator::new(2, 256, BackpressurePolicy::Block));
    let server = Server::start("127.0.0.1:0", c, 4).expect("server");
    let addr = server.addr().to_string();
    (server, addr)
}

#[test]
fn full_client_workflow() {
    let (_server, addr) = start_server();
    let mut cl = Client::connect(&addr).expect("connect");
    cl.ping().expect("ping");

    cl.register("layer0", 4, "awa3(c=0.5)").expect("register");
    cl.register("bn", 2, "gea(c=0.25)").expect("register");
    let mut names = cl.list_streams().expect("list");
    names.sort();
    assert_eq!(names, vec!["bn".to_string(), "layer0".to_string()]);

    for t in 1..=100u64 {
        assert!(cl.push("layer0", &[t as f64; 4]).expect("push"));
        assert!(cl.push("bn", &[t as f64, -(t as f64)]).expect("push"));
    }
    cl.sync().expect("sync");

    let snap = cl.snapshot("layer0").expect("snapshot");
    assert_eq!(snap.t, 100);
    assert_eq!(snap.value.as_ref().unwrap().len(), 4);
    assert!(snap.window_len > 0.0);

    let metrics = cl.metrics().expect("metrics");
    assert_eq!(
        metrics
            .get("streams")
            .and_then(|s| s.as_arr())
            .map(<[_]>::len),
        Some(2)
    );
}

#[test]
fn server_reports_errors_not_disconnects() {
    let (_server, addr) = start_server();
    let mut cl = Client::connect(&addr).expect("connect");

    // Unknown stream
    let err = cl.push("ghost", &[1.0]).unwrap_err();
    assert!(err.contains("ghost"), "{err}");
    // Bad spec
    let err = cl.register("x", 2, "bogus(c=1)").unwrap_err();
    assert!(err.contains("bogus"), "{err}");
    // Wrong dims
    cl.register("x", 2, "gea(c=0.5)").unwrap();
    let err = cl.push("x", &[1.0]).unwrap_err();
    assert!(err.contains("dims"), "{err}");
    // Duplicate register
    let err = cl.register("x", 2, "gea(c=0.5)").unwrap_err();
    assert!(err.contains("already"), "{err}");
    // Connection still healthy afterwards.
    cl.ping().expect("connection survives errors");
}

#[test]
fn multiple_concurrent_clients() {
    let (_server, addr) = start_server();
    let mut setup = Client::connect(&addr).unwrap();
    setup.register("shared", 1, "true(k=1)").unwrap();
    drop(setup);

    let mut handles = Vec::new();
    for i in 0..4 {
        let addr = addr.clone();
        handles.push(std::thread::spawn(move || {
            let mut cl = Client::connect(&addr).unwrap();
            for t in 0..250 {
                cl.push("shared", &[(i * 1000 + t) as f64]).unwrap();
            }
            cl.sync().unwrap();
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let mut cl = Client::connect(&addr).unwrap();
    let snap = cl.snapshot("shared").unwrap();
    assert_eq!(snap.t, 1000);
}

#[test]
fn push_many_batches_apply_in_order() {
    let (_server, addr) = start_server();
    let mut cl = Client::connect(&addr).unwrap();
    cl.register("batch", 2, "true(k=1)").unwrap();
    // 100 samples in one round-trip; true(k=1) keeps only the last.
    let mut flat = Vec::with_capacity(200);
    for i in 1..=100u64 {
        flat.push(i as f64);
        flat.push(-(i as f64));
    }
    let (accepted, dropped) = cl.push_many("batch", 100, &flat).unwrap();
    assert_eq!((accepted, dropped), (100, 0));
    cl.sync().unwrap();
    let snap = cl.snapshot("batch").unwrap();
    assert_eq!(snap.t, 100);
    assert_eq!(snap.value.unwrap(), vec![100.0, -100.0]);
}

#[test]
fn push_many_rejects_wrong_dim() {
    let (_server, addr) = start_server();
    let mut cl = Client::connect(&addr).unwrap();
    cl.register("b", 3, "gea(c=0.5)").unwrap();
    // 10 floats, count 5 → dim 2 != 3.
    let err = cl.push_many("b", 5, &[0.0; 10]).unwrap_err();
    assert!(err.contains("dims"), "{err}");
    cl.ping().unwrap();
}

#[test]
fn push_many_zero_count_and_ragged_get_structured_error_frames() {
    use ata::coordinator::protocol::{read_frame, write_frame, Request};
    use ata::util::json::Json;
    let (_server, addr) = start_server();
    {
        let mut cl = Client::connect(&addr).expect("connect");
        cl.register("w", 2, "gea(c=0.5)").unwrap();
    }
    // Drive the wire protocol directly so malformed batches actually
    // cross the server round-trip (the Client would pre-validate).
    let mut raw = std::net::TcpStream::connect(&addr).expect("raw connect");
    raw.set_nodelay(true).unwrap();
    for (count, data_len) in [(0.0, 0usize), (0.0, 4), (3.0, 4)] {
        let req = Json::obj(vec![
            ("op", Json::Str("push_many".into())),
            ("stream", Json::Str("w".into())),
            ("count", Json::Num(count)),
            ("data", Json::nums(&vec![1.0; data_len])),
        ]);
        write_frame(&mut raw, &req).unwrap();
        let resp = read_frame(&mut raw).unwrap().expect("response frame");
        assert_eq!(
            resp.get("ok").and_then(Json::as_bool),
            Some(false),
            "count={count} len={data_len} must be an error frame: {resp:?}"
        );
        let err = resp.get("error").and_then(Json::as_str).unwrap();
        assert!(err.contains("do not split"), "{err}");
    }
    // A batch whose shape is self-consistent but wrong for the stream's
    // declared dim is also a structured error, not a disconnect.
    let req = Request::PushMany {
        stream: "w".into(),
        count: 2,
        data: vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0], // dim 3 != 2
    }
    .to_json();
    write_frame(&mut raw, &req).unwrap();
    let resp = read_frame(&mut raw).unwrap().unwrap();
    assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(false));
    assert!(resp
        .get("error")
        .and_then(Json::as_str)
        .unwrap()
        .contains("dims"));
    // Connection still healthy afterwards; nothing was applied.
    write_frame(&mut raw, &Request::Ping.to_json()).unwrap();
    let pong = read_frame(&mut raw).unwrap().unwrap();
    assert_eq!(pong.get("ok").and_then(Json::as_bool), Some(true));
    let mut cl = Client::connect(&addr).unwrap();
    cl.sync().unwrap();
    assert_eq!(cl.snapshot("w").unwrap().t, 0);
}

#[test]
fn push_many_batched_path_matches_per_sample_path() {
    let (_server, addr) = start_server();
    let mut cl = Client::connect(&addr).unwrap();
    cl.register("batched", 3, "awa3(c=0.5)").unwrap();
    cl.register("single", 3, "awa3(c=0.5)").unwrap();
    let mut flat = Vec::new();
    for i in 1..=60u64 {
        flat.extend_from_slice(&[i as f64, (i as f64).sqrt(), -(i as f64)]);
    }
    // Mixed batch sizes through the wire, vs one-at-a-time pushes.
    let (a1, _) = cl.push_many("batched", 1, &flat[..3]).unwrap();
    let (a2, _) = cl.push_many("batched", 9, &flat[3..30]).unwrap();
    let (a3, _) = cl.push_many("batched", 50, &flat[30..]).unwrap();
    assert_eq!(a1 + a2 + a3, 60);
    for chunk in flat.chunks_exact(3) {
        cl.push("single", chunk).unwrap();
    }
    cl.sync().unwrap();
    let a = cl.snapshot("batched").unwrap();
    let b = cl.snapshot("single").unwrap();
    assert_eq!(a.t, 60);
    assert_eq!(b.t, 60);
    let (va, vb) = (a.value.unwrap(), b.value.unwrap());
    for i in 0..3 {
        assert!(
            (va[i] - vb[i]).abs() < 1e-12,
            "dim {i}: batched {} vs single {}",
            va[i],
            vb[i]
        );
    }
}

#[test]
fn snapshot_of_empty_stream_has_null_value() {
    let (_server, addr) = start_server();
    let mut cl = Client::connect(&addr).unwrap();
    cl.register("empty", 3, "gea(c=0.5)").unwrap();
    let snap = cl.snapshot("empty").unwrap();
    assert_eq!(snap.t, 0);
    assert!(snap.value.is_none());
}

#[test]
fn server_shutdown_is_clean() {
    let (mut server, addr) = start_server();
    let mut cl = Client::connect(&addr).unwrap();
    cl.ping().unwrap();
    server.shutdown();
    // New connections must fail after shutdown... the listener socket is
    // closed; allow either immediate failure or failure on first use.
    match Client::connect(&addr) {
        Err(_) => {}
        Ok(mut c2) => {
            let _ = c2.set_timeout(Some(std::time::Duration::from_millis(200)));
            assert!(c2.ping().is_err());
        }
    }
}

#[test]
fn state_transfer_ops_over_the_wire() {
    // export_state → restore moves a stream's estimator state between
    // two independent servers; merge_state rolls a partial in.
    let (_sa, addr_a) = start_server();
    let (_sb, addr_b) = start_server();
    let mut ca = Client::connect(&addr_a).expect("connect a");
    let mut cb = Client::connect(&addr_b).expect("connect b");
    for cl in [&mut ca, &mut cb] {
        cl.register("w", 2, "gea(c=0.5)").unwrap();
        cl.register("tw", 1, "true(k=3)").unwrap();
    }
    let flat: Vec<f64> = (0..40).map(|i| (i as f64 * 0.3).sin()).collect();
    ca.push_many("w", 20, &flat).unwrap();
    ca.sync().unwrap();
    // Banked stream state over the wire.
    let state = ca.export_state("w").expect("export");
    assert!(!state.is_empty());
    assert_eq!(cb.restore("w", &state).expect("restore"), 20);
    let (sa, sb) = (ca.snapshot("w").unwrap(), cb.snapshot("w").unwrap());
    assert_eq!(sa.t, sb.t);
    assert_eq!(sa.value.unwrap(), sb.value.unwrap());
    // Slot-backed stream too.
    for t in 1..=5u64 {
        ca.push("tw", &[t as f64]).unwrap();
    }
    ca.sync().unwrap();
    let state = ca.export_state("tw").expect("export tw");
    assert_eq!(cb.restore("tw", &state).expect("restore tw"), 5);
    // merge_state: a longer 'true' window takes precedence.
    for t in 1..=9u64 {
        cb.push("tw", &[100.0 + t as f64]).unwrap();
    }
    cb.sync().unwrap();
    let partial = cb.export_state("tw").unwrap();
    assert_eq!(ca.merge_state("tw", &partial).expect("merge"), 14);
    // Corrupt payloads come back as structured errors, not disconnects.
    let err = ca.restore("w", b"junk").unwrap_err();
    assert!(!err.is_empty());
    ca.ping().expect("connection still alive");
}

#[test]
fn checkpoint_op_requires_persist_and_works_with_it() {
    use ata::config::{PersistConfig, ServiceConfig};
    // Without a [persist] section the op is a structured error.
    let (_server, addr) = start_server();
    let mut cl = Client::connect(&addr).expect("connect");
    let err = cl.checkpoint().unwrap_err();
    assert!(err.contains("persist"), "{err}");
    cl.ping().expect("still alive");
    // With one, the snapshot lands on disk and reports its streams.
    let dir = ata::testkit::temp_dir("svc-checkpoint");
    let cfg = ServiceConfig {
        shards: 2,
        persist: Some(PersistConfig {
            dir: dir.display().to_string(),
            ..Default::default()
        }),
        ..Default::default()
    };
    let c = Arc::new(Coordinator::from_config(&cfg).unwrap());
    let server = Server::start("127.0.0.1:0", c, 2).expect("server");
    let mut cl = Client::connect(&server.addr().to_string()).expect("connect");
    cl.register("w", 2, "gea(c=0.5)").unwrap();
    cl.push_many("w", 4, &[1.0; 8]).unwrap();
    cl.sync().unwrap();
    let (path, streams) = cl.checkpoint().expect("checkpoint");
    assert_eq!(streams, 1);
    assert!(std::path::Path::new(&path).exists(), "{path}");
    let _ = std::fs::remove_dir_all(&dir);
}
