//! Integration: TCP server + client over localhost.

use ata::config::BackpressurePolicy;
use ata::coordinator::{Client, Coordinator, Server};
use std::sync::Arc;

fn start_server() -> (Server, String) {
    let c = Arc::new(Coordinator::new(2, 256, BackpressurePolicy::Block));
    let server = Server::start("127.0.0.1:0", c, 4).expect("server");
    let addr = server.addr().to_string();
    (server, addr)
}

#[test]
fn full_client_workflow() {
    let (_server, addr) = start_server();
    let mut cl = Client::connect(&addr).expect("connect");
    cl.ping().expect("ping");

    cl.register("layer0", 4, "awa3(c=0.5)").expect("register");
    cl.register("bn", 2, "gea(c=0.25)").expect("register");
    let mut names = cl.list_streams().expect("list");
    names.sort();
    assert_eq!(names, vec!["bn".to_string(), "layer0".to_string()]);

    for t in 1..=100u64 {
        assert!(cl.push("layer0", &[t as f64; 4]).expect("push"));
        assert!(cl.push("bn", &[t as f64, -(t as f64)]).expect("push"));
    }
    cl.sync().expect("sync");

    let snap = cl.snapshot("layer0").expect("snapshot");
    assert_eq!(snap.t, 100);
    assert_eq!(snap.value.as_ref().unwrap().len(), 4);
    assert!(snap.window_len > 0.0);

    let metrics = cl.metrics().expect("metrics");
    assert_eq!(
        metrics
            .get("streams")
            .and_then(|s| s.as_arr())
            .map(<[_]>::len),
        Some(2)
    );
}

#[test]
fn server_reports_errors_not_disconnects() {
    let (_server, addr) = start_server();
    let mut cl = Client::connect(&addr).expect("connect");

    // Unknown stream
    let err = cl.push("ghost", &[1.0]).unwrap_err();
    assert!(err.contains("ghost"), "{err}");
    // Bad spec
    let err = cl.register("x", 2, "bogus(c=1)").unwrap_err();
    assert!(err.contains("bogus"), "{err}");
    // Wrong dims
    cl.register("x", 2, "gea(c=0.5)").unwrap();
    let err = cl.push("x", &[1.0]).unwrap_err();
    assert!(err.contains("dims"), "{err}");
    // Duplicate register
    let err = cl.register("x", 2, "gea(c=0.5)").unwrap_err();
    assert!(err.contains("already"), "{err}");
    // Connection still healthy afterwards.
    cl.ping().expect("connection survives errors");
}

#[test]
fn multiple_concurrent_clients() {
    let (_server, addr) = start_server();
    let mut setup = Client::connect(&addr).unwrap();
    setup.register("shared", 1, "true(k=1)").unwrap();
    drop(setup);

    let mut handles = Vec::new();
    for i in 0..4 {
        let addr = addr.clone();
        handles.push(std::thread::spawn(move || {
            let mut cl = Client::connect(&addr).unwrap();
            for t in 0..250 {
                cl.push("shared", &[(i * 1000 + t) as f64]).unwrap();
            }
            cl.sync().unwrap();
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let mut cl = Client::connect(&addr).unwrap();
    let snap = cl.snapshot("shared").unwrap();
    assert_eq!(snap.t, 1000);
}

#[test]
fn push_many_batches_apply_in_order() {
    let (_server, addr) = start_server();
    let mut cl = Client::connect(&addr).unwrap();
    cl.register("batch", 2, "true(k=1)").unwrap();
    // 100 samples in one round-trip; true(k=1) keeps only the last.
    let mut flat = Vec::with_capacity(200);
    for i in 1..=100u64 {
        flat.push(i as f64);
        flat.push(-(i as f64));
    }
    let (accepted, dropped) = cl.push_many("batch", 100, &flat).unwrap();
    assert_eq!((accepted, dropped), (100, 0));
    cl.sync().unwrap();
    let snap = cl.snapshot("batch").unwrap();
    assert_eq!(snap.t, 100);
    assert_eq!(snap.value.unwrap(), vec![100.0, -100.0]);
}

#[test]
fn push_many_rejects_wrong_dim() {
    let (_server, addr) = start_server();
    let mut cl = Client::connect(&addr).unwrap();
    cl.register("b", 3, "gea(c=0.5)").unwrap();
    // 10 floats, count 5 → dim 2 != 3.
    let err = cl.push_many("b", 5, &[0.0; 10]).unwrap_err();
    assert!(err.contains("dims"), "{err}");
    cl.ping().unwrap();
}

#[test]
fn snapshot_of_empty_stream_has_null_value() {
    let (_server, addr) = start_server();
    let mut cl = Client::connect(&addr).unwrap();
    cl.register("empty", 3, "gea(c=0.5)").unwrap();
    let snap = cl.snapshot("empty").unwrap();
    assert_eq!(snap.t, 0);
    assert!(snap.value.is_none());
}

#[test]
fn server_shutdown_is_clean() {
    let (mut server, addr) = start_server();
    let mut cl = Client::connect(&addr).unwrap();
    cl.ping().unwrap();
    server.shutdown();
    // New connections must fail after shutdown... the listener socket is
    // closed; allow either immediate failure or failure on first use.
    match Client::connect(&addr) {
        Err(_) => {}
        Ok(mut c2) => {
            let _ = c2.set_timeout(Some(std::time::Duration::from_millis(200)));
            assert!(c2.ping().is_err());
        }
    }
}
